//! `Scenario` — the fluent entry point for running experiments on any
//! backend.
//!
//! One protocol, every backend: a scenario describes *what* to run (task,
//! protocol, reliability, scale) and *where* to run it ([`Backend::Sim`]
//! on the virtual clock, [`Backend::Live`] on the threaded cluster), and
//! returns the same [`RunResult`] either way.
//!
//! ```no_run
//! use hybridfl::config::ProtocolKind;
//! use hybridfl::scenario::{Backend, Scenario};
//!
//! let result = Scenario::task1()
//!     .protocol(ProtocolKind::HybridFl)
//!     .dropout(0.3)
//!     .backend(Backend::Live)
//!     .seed(42)
//!     .run()?;
//! println!("best accuracy: {:.3}", result.summary.best_accuracy);
//! # Ok::<(), anyhow::Error>(())
//! ```

use std::path::PathBuf;

use crate::churn::ChurnModel;
use crate::config::{CacheMode, EngineKind, ExperimentConfig, ProtocolKind};
use crate::env::{
    run_resumable, DriverState, FlEnvironment, LiveClusterEnv, RunResult, VirtualClockEnv,
};
use crate::ops::{CheckpointPlan, OpsServer, RunControl, RunInfo, RunObserver};
use crate::protocols::protocol_for;
use crate::snapshot::{self, CodecKind};
use crate::trace::TraceWriter;
use crate::Result;

/// Which [`crate::env::FlEnvironment`] implementation executes the rounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Deterministic MEC simulator on the virtual clock (default).
    Sim,
    /// Live threaded cloud/edge/client cluster (mock numerics, real
    /// concurrency; virtual durations scaled by
    /// [`Scenario::time_scale`]).
    Live,
}

impl Backend {
    pub fn as_str(self) -> &'static str {
        match self {
            Backend::Sim => "sim",
            Backend::Live => "live",
        }
    }

    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "sim" => Ok(Backend::Sim),
            "live" => Ok(Backend::Live),
            _ => anyhow::bail!("unknown backend '{s}' (sim|live)"),
        }
    }
}

/// Builder for one experiment run. Start from a preset, chain overrides,
/// pick a backend, `run()`.
#[derive(Clone, Debug)]
pub struct Scenario {
    cfg: ExperimentConfig,
    backend: Backend,
    time_scale: f64,
    checkpoint_dir: Option<PathBuf>,
    checkpoint_every: Option<usize>,
    resume_from: Option<PathBuf>,
    snapshot_codec: CodecKind,
    record_fates: Option<PathBuf>,
    serial_fold: bool,
    eager_sweeps: bool,
    ops_listen: Option<String>,
    ops_token: Option<String>,
    trace_out: Option<PathBuf>,
}

impl Scenario {
    /// Default wall-clock seconds per virtual second for the live backend
    /// (a ~90 s virtual deadline plays out in ~9 ms).
    pub const DEFAULT_TIME_SCALE: f64 = 1e-4;

    /// Wrap an existing config (the escape hatch for fully custom setups).
    pub fn from_config(cfg: ExperimentConfig) -> Scenario {
        Scenario {
            cfg,
            backend: Backend::Sim,
            time_scale: Self::DEFAULT_TIME_SCALE,
            checkpoint_dir: None,
            checkpoint_every: None,
            resume_from: None,
            snapshot_codec: CodecKind::Binary,
            record_fates: None,
            serial_fold: false,
            eager_sweeps: false,
            ops_listen: None,
            ops_token: None,
            trace_out: None,
        }
    }

    /// Task 1 (Aerofoil) at laptop scale.
    pub fn task1() -> Scenario {
        Self::from_config(ExperimentConfig::task1_scaled())
    }

    /// Task 1 (Aerofoil) at exact Table II scale.
    pub fn task1_paper() -> Scenario {
        Self::from_config(ExperimentConfig::task1_paper())
    }

    /// Task 2 (MNIST) at laptop scale.
    pub fn task2() -> Scenario {
        Self::from_config(ExperimentConfig::task2_scaled())
    }

    /// Task 2 (MNIST) at exact Table II scale.
    pub fn task2_paper() -> Scenario {
        Self::from_config(ExperimentConfig::task2_paper())
    }

    /// The Fig. 2 slack-trace experiment (mock engine, two regions).
    pub fn fig2() -> Scenario {
        Self::from_config(ExperimentConfig::fig2())
    }

    /// Any named preset (`task1|task1-scaled|task2|task2-scaled|fig2`).
    pub fn preset(name: &str) -> Result<Scenario> {
        Ok(Self::from_config(ExperimentConfig::preset(name)?))
    }

    // --- config overrides ---------------------------------------------------

    pub fn protocol(mut self, p: ProtocolKind) -> Scenario {
        self.cfg.protocol = p;
        self
    }

    pub fn engine(mut self, e: EngineKind) -> Scenario {
        self.cfg.engine = e;
        self
    }

    /// Shorthand for the analytic mock engine (no artifacts needed).
    pub fn mock(self) -> Scenario {
        self.engine(EngineKind::Mock)
    }

    /// E[dr] — mean per-round drop-out probability of the fleet.
    pub fn dropout(mut self, mean: f64) -> Scenario {
        self.cfg.dropout.mean = mean;
        self
    }

    /// Time-varying reliability dynamics (the churn subsystem): Markov
    /// burstiness, diurnal cycles, battery drain, scripted fault events,
    /// or a composition of them. [`ChurnModel::Stationary`] (the default)
    /// reproduces the frozen-world behavior bit for bit.
    pub fn churn(mut self, model: ChurnModel) -> Scenario {
        self.cfg.churn = model;
        self
    }

    /// Communication configuration for device→edge submissions (the comm
    /// subsystem; see [`crate::comm`]): codec choice plus the optional
    /// relay axis. The dense default reproduces pre-codec behavior bit
    /// for bit; `topk+ef` is sim-only and rejected by the live backend.
    pub fn comm(mut self, comm: crate::comm::CommConfig) -> Scenario {
        self.cfg.comm = comm;
        self
    }

    /// Relay quantile: the weakest `q` fraction of each region's selected
    /// survivors hand their encoded updates to the region's fastest
    /// peers, which upload the combined frames. Composes with any codec
    /// (`.comm(..)` keeps its codec; this only sets the relay axis).
    pub fn relay(mut self, q: f64) -> Scenario {
        self.cfg.comm.relay = Some(q);
        self
    }

    /// Client-selection strategy (the selection zoo; see
    /// [`crate::selection`]). [`SelectorKind::Slack`] (the default) is
    /// the paper's estimator and reproduces pre-zoo behavior bit for
    /// bit; [`SelectorKind::Oracle`] is sim-only and rejected by the
    /// live backend.
    ///
    /// [`SelectorKind::Slack`]: crate::selection::SelectorKind::Slack
    /// [`SelectorKind::Oracle`]: crate::selection::SelectorKind::Oracle
    pub fn selector(mut self, kind: crate::selection::SelectorKind) -> Scenario {
        self.cfg.selector = kind;
        self
    }

    /// Record the run's ground-truth per-round fates and write them as a
    /// [`crate::churn::FateTrace`] JSON at `path` when the run completes.
    /// Observational: recording never perturbs the run (and composes with
    /// [`Self::replay_fates`] — replay + record is the fixed-point check).
    pub fn record_fates(mut self, path: impl Into<PathBuf>) -> Scenario {
        self.record_fates = Some(path.into());
        self
    }

    /// Replay the ground-truth fates of a recorded (or hand-written)
    /// trace instead of drawing them — shorthand for
    /// `.churn(ChurnModel::Replay { path })`.
    pub fn replay_fates(mut self, path: impl Into<PathBuf>) -> Scenario {
        self.cfg.churn = ChurnModel::Replay {
            path: path.into().to_string_lossy().into_owned(),
        };
        self
    }

    /// C — desired proportion of clients with successful submissions.
    pub fn c_fraction(mut self, c: f64) -> Scenario {
        self.cfg.c_fraction = c;
        self
    }

    pub fn seed(mut self, seed: u64) -> Scenario {
        self.cfg.seed = seed;
        self
    }

    /// t_max — number of federated rounds to run.
    pub fn rounds(mut self, t_max: usize) -> Scenario {
        self.cfg.t_max = t_max;
        self
    }

    pub fn clients(mut self, n: usize) -> Scenario {
        self.cfg.n_clients = n;
        self
    }

    pub fn edges(mut self, m: usize) -> Scenario {
        self.cfg.n_edges = m;
        self
    }

    pub fn dataset_size(mut self, n: usize) -> Scenario {
        self.cfg.dataset_size = n;
        self
    }

    pub fn local_epochs(mut self, tau: usize) -> Scenario {
        self.cfg.local_epochs = tau;
        self
    }

    pub fn theta_init(mut self, theta: f64) -> Scenario {
        self.cfg.theta_init = theta;
        self
    }

    pub fn cache_mode(mut self, mode: CacheMode) -> Scenario {
        self.cfg.cache_mode = mode;
        self
    }

    /// Stop early once the global model reaches this accuracy.
    pub fn target_accuracy(mut self, acc: f64) -> Scenario {
        self.cfg.target_accuracy = Some(acc);
        self
    }

    /// Arbitrary config surgery for knobs without a dedicated method.
    pub fn tune(mut self, f: impl FnOnce(&mut ExperimentConfig)) -> Scenario {
        f(&mut self.cfg);
        self
    }

    /// Apply CLI-style `key=value` overrides (see `config::apply_overrides`).
    pub fn apply_sets(mut self, overrides: &[String]) -> Result<Scenario> {
        crate::config::apply_overrides(&mut self.cfg, overrides)?;
        Ok(self)
    }

    // --- execution ----------------------------------------------------------

    pub fn backend(mut self, backend: Backend) -> Scenario {
        self.backend = backend;
        self
    }

    /// Wall-clock seconds per virtual second for [`Backend::Live`].
    pub fn time_scale(mut self, scale: f64) -> Scenario {
        self.time_scale = scale;
        self
    }

    /// Force the virtual clock's serial fold path even when a round
    /// qualifies for the parallel per-region fold. Debug/verification
    /// knob — the two paths are byte-identical by contract (pinned in
    /// `tests/scale_identity.rs`), so this only trades wall-clock for a
    /// single-threaded execution. Not part of the experiment config:
    /// snapshots from either path are interchangeable.
    pub fn serial_fold(mut self, on: bool) -> Scenario {
        self.serial_fold = on;
        self
    }

    /// Recompute the virtual clock's availability sweep from the full
    /// fleet every round instead of reading the incremental cache.
    /// Debug/verification knob — the lazy cache is byte-identical by
    /// contract (pinned in `tests/scale_identity.rs`). Not part of the
    /// experiment config.
    pub fn eager_sweeps(mut self, on: bool) -> Scenario {
        self.eager_sweeps = on;
        self
    }

    // --- checkpoint / resume ------------------------------------------------

    /// Write a [`RunSnapshot`] into `dir` at round boundaries (every
    /// round unless [`Self::checkpoint_every`] widens the cadence).
    /// Snapshots are named `snapshot_round_NNNNNN.<ext>` and written
    /// atomically.
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Scenario {
        self.checkpoint_dir = Some(dir.into());
        self
    }

    /// Checkpoint every `n` completed rounds (requires
    /// [`Self::checkpoint_dir`]; `run()` rejects the combination
    /// otherwise).
    pub fn checkpoint_every(mut self, n: usize) -> Scenario {
        self.checkpoint_every = Some(n);
        self
    }

    /// Resume from a snapshot file written by a previous run of the
    /// *same* experiment. The snapshot's config fingerprint must match
    /// this scenario's config exactly — a divergence is a hard error
    /// naming the differing fields — and the backend must match too. The
    /// resumed run's [`RunResult`] is byte-identical to what the
    /// uninterrupted run would have produced.
    pub fn resume_from(mut self, path: impl Into<PathBuf>) -> Scenario {
        self.resume_from = Some(path.into());
        self
    }

    /// Which codec checkpoints are written with (binary by default;
    /// [`CodecKind::Json`] for human-readable debugging snapshots).
    pub fn snapshot_codec(mut self, kind: CodecKind) -> Scenario {
        self.snapshot_codec = kind;
        self
    }

    // --- ops endpoint -------------------------------------------------------

    /// Serve the operations control plane on `addr` while the run is in
    /// flight: a Prometheus-text `/metrics` scrape plus a line-oriented
    /// control socket (`pause`/`resume`, `checkpoint-now`, live fault
    /// `inject`) on one listener — see [`crate::ops`]. Like
    /// [`Self::serial_fold`], this is operational, not part of the
    /// experiment config: it never perturbs the run or its snapshots.
    pub fn ops_listen(mut self, addr: impl Into<String>) -> Scenario {
        self.ops_listen = Some(addr.into());
        self
    }

    /// Guard the ops endpoint with an access token: `/metrics` requires
    /// `?token=TOKEN` and control sessions must open with `auth TOKEN`.
    /// Mandatory when [`Self::ops_listen`] names a non-loopback address
    /// (the bind is refused otherwise — see
    /// [`OpsServer::bind_with_token`]).
    pub fn ops_token(mut self, token: impl Into<String>) -> Scenario {
        self.ops_token = Some(token.into());
        self
    }

    /// Write a Chrome trace-event JSON of every round-phase span to
    /// `path` when the run completes — load it in Perfetto /
    /// `chrome://tracing` (see [`crate::trace::TraceWriter`]). Like the
    /// ops endpoint, tracing is observational: the traced run is
    /// byte-identical to a plain one.
    pub fn trace_out(mut self, path: impl Into<PathBuf>) -> Scenario {
        self.trace_out = Some(path.into());
        self
    }

    /// The resolved config (inspection / serialization).
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Validate the config, build the backend and the protocol, restore a
    /// snapshot when resuming, and drive the run to completion —
    /// checkpointing at round boundaries when a checkpoint dir is set and
    /// serving the ops endpoint when [`Self::ops_listen`] is set.
    /// Identical [`RunResult`] shape on every backend.
    pub fn run(self) -> Result<RunResult> {
        self.run_observed(&mut [])
    }

    /// Like [`Self::run`], with caller-supplied [`RunObserver`]s attached
    /// to the round-boundary event stream (in slice order, ahead of any
    /// [`Self::trace_out`] writer). This is how the CLI streams its CSV
    /// trace ([`crate::metrics::ReportSink`]) from the same events the
    /// ops endpoint consumes.
    pub fn run_observed(mut self, observers: &mut [&mut dyn RunObserver]) -> Result<RunResult> {
        let server = match &self.ops_listen {
            Some(addr) => Some(OpsServer::bind_with_token(
                addr.as_str(),
                self.ops_token.take(),
            )?),
            None => {
                anyhow::ensure!(
                    self.ops_token.is_none(),
                    "ops_token without ops_listen: the token guards the ops endpoint, \
                     which this run does not serve"
                );
                None
            }
        };
        self.run_inner(server, observers)
    }

    /// Like [`Self::run`], but serve the ops endpoint on an
    /// already-bound [`OpsServer`] — the way to run against an
    /// OS-assigned port (`OpsServer::bind("127.0.0.1:0")`, read
    /// [`OpsServer::local_addr`], then hand the server over).
    pub fn run_with_ops(self, server: OpsServer) -> Result<RunResult> {
        anyhow::ensure!(
            self.ops_token.is_none(),
            "ops_token is applied at bind time: either use ops_listen + ops_token, or \
             bind yourself with OpsServer::bind_with_token and pass the server here"
        );
        self.run_inner(Some(server), &mut [])
    }

    fn run_inner(
        self,
        ops_server: Option<OpsServer>,
        observers: &mut [&mut dyn RunObserver],
    ) -> Result<RunResult> {
        self.cfg.validate()?;
        if self.checkpoint_every.is_some() && self.checkpoint_dir.is_none() {
            anyhow::bail!("checkpoint_every(n) requires checkpoint_dir(..)");
        }
        if let Some(every) = self.checkpoint_every {
            anyhow::ensure!(every > 0, "checkpoint_every must be >= 1");
        }
        if self.record_fates.is_some() && self.resume_from.is_some() {
            anyhow::bail!(
                "record_fates on a resumed run would write a partial trace: rounds \
                 up to the checkpoint are restored from the snapshot, not executed, \
                 so their fates cannot be recorded — record from a fresh run instead"
            );
        }

        let backend = self.backend;
        let mut env: Box<dyn FlEnvironment> = match backend {
            Backend::Sim => {
                let mut env = VirtualClockEnv::new(self.cfg.clone())?;
                env.set_serial_fold(self.serial_fold);
                env.set_eager_sweeps(self.eager_sweeps);
                Box::new(env)
            }
            Backend::Live => Box::new(LiveClusterEnv::new(self.cfg.clone(), self.time_scale)?),
        };
        let mut protocol = protocol_for(env.as_ref());

        let driver = match &self.resume_from {
            Some(path) => snapshot::load_snapshot(path)?.resume_into(
                backend.as_str(),
                env.as_mut(),
                protocol.as_mut(),
            )?,
            None => DriverState::fresh(),
        };

        if self.record_fates.is_some() {
            env.set_fate_recording(true);
        }

        // Declared before `ctl` so the borrow it hands over outlives it.
        let mut trace_writer = self.trace_out.as_ref().map(|p| TraceWriter::new(p.clone()));

        let mut ctl = RunControl::new().backend(backend.as_str());
        for obs in observers.iter_mut() {
            ctl = ctl.observe_with(&mut **obs);
        }
        if let Some(tw) = trace_writer.as_mut() {
            ctl = ctl.observe_with(tw);
        }
        if let Some(dir) = &self.checkpoint_dir {
            ctl = ctl.checkpoints(CheckpointPlan {
                dir: dir.clone(),
                kind: self.snapshot_codec,
                every: self.checkpoint_every.unwrap_or(1),
            });
        }
        let mut server = ops_server;
        if let Some(server) = server.as_mut() {
            let info = RunInfo {
                backend: backend.as_str().to_string(),
                protocol: self.cfg.protocol.as_str().to_string(),
                region_sizes: (0..env.n_regions()).map(|r| env.region_size(r)).collect(),
            };
            ctl = ctl.ops(server.attach(info)?);
        }

        let result = run_resumable(env.as_mut(), protocol.as_mut(), driver, &mut ctl)?;

        if let Some(path) = &self.record_fates {
            let trace = env
                .take_fate_trace()
                .expect("recording was enabled before the run");
            trace.save(path)?;
        }
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains_and_exposes_config() {
        let sc = Scenario::task1()
            .mock()
            .protocol(ProtocolKind::FedAvg)
            .dropout(0.4)
            .c_fraction(0.2)
            .seed(7)
            .rounds(12)
            .comm(crate::comm::CommConfig::parse_spec("topk:0.05+ef").unwrap())
            .relay(0.25);
        assert_eq!(sc.config().protocol, ProtocolKind::FedAvg);
        assert_eq!(sc.config().engine, EngineKind::Mock);
        assert_eq!(sc.config().dropout.mean, 0.4);
        assert_eq!(sc.config().c_fraction, 0.2);
        assert_eq!(sc.config().seed, 7);
        assert_eq!(sc.config().t_max, 12);
        assert!(sc.config().comm.codec.has_error_feedback());
        assert_eq!(sc.config().comm.relay, Some(0.25));
    }

    // Validation rejection cases live in tests/scenario_api.rs
    // (builder_rejects_invalid_fraction_and_quota_combos).

    #[test]
    fn checkpoint_every_without_dir_is_rejected() {
        let err = Scenario::task1()
            .mock()
            .rounds(2)
            .clients(8)
            .edges(2)
            .checkpoint_every(1)
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("checkpoint_dir"), "{err}");
    }

    #[test]
    fn ops_token_without_listen_is_rejected() {
        let err = Scenario::task1()
            .mock()
            .rounds(1)
            .clients(8)
            .edges(2)
            .ops_token("s3cret")
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("ops_listen"), "{err}");
    }

    #[test]
    fn resume_from_missing_file_reports_path() {
        let err = Scenario::task1()
            .mock()
            .rounds(2)
            .clients(8)
            .edges(2)
            .resume_from("/nonexistent/snapshot.hflsnap")
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("/nonexistent/snapshot.hflsnap"), "{err}");
    }

    #[test]
    fn sim_run_matches_flrun() {
        let sc = Scenario::task1().mock().rounds(8).clients(16).edges(2);
        let cfg = sc.config().clone();
        let a = sc.run().unwrap();
        let b = crate::sim::FlRun::new(cfg).unwrap().run().unwrap();
        assert_eq!(a.summary.best_accuracy, b.summary.best_accuracy);
        assert_eq!(a.summary.total_time, b.summary.total_time);
    }
}
