//! Round-timing model (S6): equations (31)–(34) of the paper.
//!
//! * Client communication (33): `T_k^comm = 3 · msize / (bw_k · log2(1+SNR))`
//!   — Shannon-effective bitrate of the shared wireless channel; the 3×
//!   factor models upload at half the downlink rate (1× down + 2× up).
//! * Client training (34): `T_k^train = |D_k| · τ · BPS · CPB / s_k`.
//! * Cloud↔edge (32): `T_c2e2c = 3 · msize · m / BR` (zero for FedAvg,
//!   which has no edge layer).
//! * Response limit: `T_lim` is the completion time of an *extreme
//!   straggler* — a hypothetical client at μ−3σ performance and bandwidth
//!   holding an average-size partition (§IV.A).
//!
//! Units: config carries GHz/MHz/MB/Mbps (paper units); this module
//! converts to Hz/bits/seconds once at construction.

use crate::comm::CommConfig;
use crate::config::ExperimentConfig;
use crate::devices::ClientProfile;

/// Precomputed timing coefficients for one experiment.
#[derive(Clone, Debug)]
pub struct TimingModel {
    /// Model size in bits.
    msize_bits: f64,
    /// log2(1 + SNR) — spectral efficiency of the wireless channel.
    spectral_eff: f64,
    /// Per-epoch training cycles per sample: BPS · CPB.
    cycles_per_sample_epoch: f64,
    /// τ — local epochs per round.
    tau: f64,
    /// Cloud-edge round-trip (eq. 32) for the 3-layer protocols.
    pub t_c2e2c: f64,
    /// Response time limit (straggler bound).
    pub t_lim: f64,
}

impl TimingModel {
    pub fn new(cfg: &ExperimentConfig) -> TimingModel {
        let msize_bits = cfg.model_size_bits();
        let spectral_eff = (1.0 + cfg.snr).log2();
        let cycles_per_sample_epoch = cfg.bits_per_sample * cfg.cycles_per_bit;
        let t_c2e2c = 3.0 * msize_bits * cfg.n_edges as f64 / cfg.cloud_edge_bps();

        // Extreme straggler: μ − 3σ perf and bandwidth (floored at a small
        // positive value — μ−3σ can cross zero), mean partition size.
        let straggler = ClientProfile {
            perf_ghz: (cfg.perf_ghz.mean - 3.0 * cfg.perf_ghz.std).max(0.02),
            bw_mhz: (cfg.bw_mhz.mean - 3.0 * cfg.bw_mhz.std).max(0.02),
            dropout_p: 0.0,
        };
        let mut tm = TimingModel {
            msize_bits,
            spectral_eff,
            cycles_per_sample_epoch,
            tau: cfg.local_epochs as f64,
            t_c2e2c,
            t_lim: 0.0,
        };
        tm.t_lim = tm.t_comm(&straggler) + tm.t_train(&straggler, cfg.mean_partition());
        tm
    }

    /// Effective wireless bitrate (bits/s) for a `bw_mhz` MHz channel:
    /// Shannon capacity. Scalar form for the struct-of-arrays hot paths
    /// (`FleetState` sweeps read `bw_mhz[k]` straight off the flat array);
    /// the expression is exactly [`Self::effective_bps`]'s, so both forms
    /// are bit-identical.
    pub fn effective_bps_of(&self, bw_mhz: f64) -> f64 {
        bw_mhz * 1.0e6 * self.spectral_eff
    }

    /// Effective wireless bitrate for a client (bits/s): Shannon capacity
    /// of its `bw_k` MHz channel.
    pub fn effective_bps(&self, p: &ClientProfile) -> f64 {
        self.effective_bps_of(p.bw_mhz)
    }

    /// Eq. (33), scalar form (see [`Self::effective_bps_of`]).
    pub fn t_comm_of(&self, bw_mhz: f64) -> f64 {
        3.0 * self.msize_bits / self.effective_bps_of(bw_mhz)
    }

    /// Eq. (33): download + 2× upload of the model.
    pub fn t_comm(&self, p: &ClientProfile) -> f64 {
        self.t_comm_of(p.bw_mhz)
    }

    /// Number of f32 parameters in the model the config describes —
    /// what the codec layer's wire-byte accounting is denominated in.
    pub fn n_model_values(&self) -> usize {
        (self.msize_bits / 32.0) as usize
    }

    /// Upload size in bits for one encoded submission under `comm`.
    pub fn upload_bits(&self, comm: &CommConfig) -> f64 {
        8.0 * comm.codec.wire_bytes(self.n_model_values()) as f64
    }

    /// Eq. (33) generalized to encoded submissions: the downlink still
    /// moves the dense model (`msize`), the 2×-weighted uplink moves the
    /// encoded frame. The dense codec takes the *exact* legacy expression
    /// — `3·msize/bps`, not `(msize + 2·msize)/bps` — so default-config
    /// runs stay bit-identical to the pre-codec seed.
    pub fn t_comm_with(&self, p: &ClientProfile, comm: &CommConfig) -> f64 {
        self.t_comm_with_of(p.bw_mhz, comm)
    }

    /// [`Self::t_comm_with`], scalar form (see [`Self::effective_bps_of`]).
    pub fn t_comm_with_of(&self, bw_mhz: f64, comm: &CommConfig) -> f64 {
        if comm.codec.is_dense() {
            return self.t_comm_of(bw_mhz);
        }
        (self.msize_bits + 2.0 * self.upload_bits(comm)) / self.effective_bps_of(bw_mhz)
    }

    /// Eq. (34), scalar form (see [`Self::effective_bps_of`]).
    pub fn t_train_of(&self, perf_ghz: f64, partition_size: f64) -> f64 {
        partition_size * self.tau * self.cycles_per_sample_epoch / (perf_ghz * 1.0e9)
    }

    /// Eq. (34): τ full-batch GD epochs over `|D_k|` samples.
    pub fn t_train(&self, p: &ClientProfile, partition_size: f64) -> f64 {
        self.t_train_of(p.perf_ghz, partition_size)
    }

    /// Completion time of a client that does not drop out: communication
    /// plus local training (measured from round start).
    pub fn completion(&self, p: &ClientProfile, partition_size: f64) -> f64 {
        self.t_comm(p) + self.t_train(p, partition_size)
    }

    /// [`Self::completion`] under an update codec: compressed uploads
    /// shorten the communication leg, training is untouched.
    pub fn completion_with(
        &self,
        p: &ClientProfile,
        partition_size: f64,
        comm: &CommConfig,
    ) -> f64 {
        self.completion_with_of(p.perf_ghz, p.bw_mhz, partition_size, comm)
    }

    /// [`Self::completion_with`], scalar form — the `FleetState` ranking
    /// and fate hot paths feed `perf_ghz[k]` / `bw_mhz[k]` straight from
    /// the flat arrays.
    pub fn completion_with_of(
        &self,
        perf_ghz: f64,
        bw_mhz: f64,
        partition_size: f64,
        comm: &CommConfig,
    ) -> f64 {
        self.t_comm_with_of(bw_mhz, comm) + self.t_train_of(perf_ghz, partition_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dist;

    fn avg_profile(cfg: &ExperimentConfig) -> ClientProfile {
        ClientProfile {
            perf_ghz: cfg.perf_ghz.mean,
            bw_mhz: cfg.bw_mhz.mean,
            dropout_p: 0.0,
        }
    }

    /// Task-1 constants from the paper: an average client (0.5 GHz,
    /// 0.5 MHz, SNR 100) moves 3×40Mb at 0.5e6·log2(101) ≈ 3.33 Mb/s →
    /// ~36 s, and trains 100·5·384·300 cycles at 0.5 GHz → ~0.115 s.
    #[test]
    fn task1_magnitudes_match_paper() {
        let cfg = ExperimentConfig::task1_paper();
        let tm = TimingModel::new(&cfg);
        let p = avg_profile(&cfg);
        let tc = tm.t_comm(&p);
        assert!((tc - 36.0).abs() < 1.0, "t_comm={tc}");
        let tt = tm.t_train(&p, 100.0);
        assert!((tt - 0.1152).abs() < 0.001, "t_train={tt}");
        // T_c2e2c = 3·40e6·3/1e9 = 0.36 s
        assert!((tm.t_c2e2c - 0.36).abs() < 1e-9);
        // Straggler: perf 0.2 GHz, bw 0.2 MHz → T_lim ≈ 90.4 s. The paper's
        // E[dr]=0.6, C=0.5 cell reports ~90.4 s rounds = T_lim + T_c2e2c.
        assert!((tm.t_lim - 90.4).abs() < 1.0, "t_lim={}", tm.t_lim);
    }

    /// Task-2: straggler at 0.1 GHz / 0.1 MHz with a 120-sample mean
    /// partition → T_lim ≈ 375.6 s; paper's FedAvg rounds sit at ~378 s
    /// (deadline-bound) for 𝓝(1.0, 0.3²) devices and a 10 MB model.
    #[test]
    fn task2_deadline_matches_paper_scale() {
        let cfg = ExperimentConfig::task2_paper();
        let tm = TimingModel::new(&cfg);
        assert!(
            (tm.t_lim - 378.0).abs() < 15.0,
            "t_lim={} should be near the paper's 378 s rounds",
            tm.t_lim
        );
    }

    #[test]
    fn faster_devices_finish_sooner() {
        let cfg = ExperimentConfig::task1_paper();
        let tm = TimingModel::new(&cfg);
        let slow = ClientProfile { perf_ghz: 0.3, bw_mhz: 0.3, dropout_p: 0.0 };
        let fast = ClientProfile { perf_ghz: 0.8, bw_mhz: 0.8, dropout_p: 0.0 };
        assert!(tm.completion(&fast, 100.0) < tm.completion(&slow, 100.0));
        assert!(tm.t_train(&fast, 200.0) > tm.t_train(&fast, 100.0));
    }

    #[test]
    fn t_lim_floor_when_mu_minus_3sigma_negative() {
        let mut cfg = ExperimentConfig::task1_paper();
        cfg.perf_ghz = Dist::new(0.3, 0.2); // μ−3σ = −0.3 → floored
        cfg.bw_mhz = Dist::new(0.3, 0.2);
        let tm = TimingModel::new(&cfg);
        assert!(tm.t_lim.is_finite() && tm.t_lim > 0.0);
    }

    #[test]
    fn codec_shortens_the_upload_leg_and_dense_is_bit_identical() {
        let cfg = ExperimentConfig::task1_paper();
        let tm = TimingModel::new(&cfg);
        let p = avg_profile(&cfg);
        // Dense must take the exact legacy expression, not an
        // algebraically-equal rearrangement.
        let dense = crate::comm::CommConfig::default();
        assert_eq!(tm.t_comm_with(&p, &dense).to_bits(), tm.t_comm(&p).to_bits());
        assert_eq!(
            tm.completion_with(&p, 100.0, &dense).to_bits(),
            tm.completion(&p, 100.0).to_bits()
        );
        // Task 1: 40 Mb model = 1.25 M f32 values.
        assert_eq!(tm.n_model_values(), 1_250_000);
        let topk = crate::comm::CommConfig::parse_spec("topk:0.05+ef").unwrap();
        // topk:0.05 → k = 62 500 entries · 8 B = 4 Mb upload vs 40 Mb dense:
        // t_comm drops from 3·msize/bps to (msize + 2·0.1·msize)/bps.
        let expect = (1.2 * 40.0e6) / tm.effective_bps(&p);
        assert!((tm.t_comm_with(&p, &topk) - expect).abs() < 1e-9);
        assert!(tm.t_comm_with(&p, &topk) < tm.t_comm(&p) / 2.0);
        // f16 halves the upload: (1 + 2·0.5)·msize/bps = 2·msize/bps.
        let f16 = crate::comm::CommConfig::parse_spec("f16").unwrap();
        let expect = 2.0 * 40.0e6 / tm.effective_bps(&p);
        assert!((tm.t_comm_with(&p, &f16) - expect).abs() < 1e-9);
    }

    /// The scalar (`*_of`) forms are what the SoA hot paths call; they
    /// must be bit-identical to the profile forms, not merely close.
    #[test]
    fn scalar_forms_are_bit_identical_to_profile_forms() {
        let cfg = ExperimentConfig::task1_paper();
        let tm = TimingModel::new(&cfg);
        let topk = crate::comm::CommConfig::parse_spec("topk:0.05+ef").unwrap();
        let dense = crate::comm::CommConfig::default();
        for p in [
            avg_profile(&cfg),
            ClientProfile { perf_ghz: 0.31, bw_mhz: 0.77, dropout_p: 0.4 },
            ClientProfile { perf_ghz: 1.9, bw_mhz: 0.08, dropout_p: 0.0 },
        ] {
            assert_eq!(
                tm.effective_bps(&p).to_bits(),
                tm.effective_bps_of(p.bw_mhz).to_bits()
            );
            assert_eq!(tm.t_comm(&p).to_bits(), tm.t_comm_of(p.bw_mhz).to_bits());
            assert_eq!(
                tm.t_train(&p, 117.0).to_bits(),
                tm.t_train_of(p.perf_ghz, 117.0).to_bits()
            );
            for comm in [&dense, &topk] {
                assert_eq!(
                    tm.t_comm_with(&p, comm).to_bits(),
                    tm.t_comm_with_of(p.bw_mhz, comm).to_bits()
                );
                assert_eq!(
                    tm.completion_with(&p, 117.0, comm).to_bits(),
                    tm.completion_with_of(p.perf_ghz, p.bw_mhz, 117.0, comm).to_bits()
                );
            }
        }
    }

    #[test]
    fn fedavg_has_no_edge_rtt_by_protocol_not_model() {
        // The timing model always computes t_c2e2c; protocols decide
        // whether to charge it (FedAvg doesn't). Just pin the formula.
        let cfg = ExperimentConfig::task2_paper();
        let tm = TimingModel::new(&cfg);
        let expect = 3.0 * cfg.model_size_bits() * 10.0 / 1.0e9;
        assert!((tm.t_c2e2c - expect).abs() < 1e-9);
    }
}
