//! Core dataset containers shared by both tasks.

/// A dense, row-major dataset: `x` is `[n, feat_len]`, `y` is `[n]`
/// (regression target, or a class label stored as f32 — the AOT graphs take
/// all inputs as f32 and cast internally).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    /// Logical feature dimensions, e.g. `[5]` (Aerofoil) or `[1, 28, 28]`.
    pub feature_dims: Vec<usize>,
    pub n: usize,
}

impl Dataset {
    pub fn feat_len(&self) -> usize {
        self.feature_dims.iter().product()
    }

    /// Feature row for sample `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        let f = self.feat_len();
        &self.x[i * f..(i + 1) * f]
    }

    /// Mean absolute deviation of `y` around its mean — the normalizer for
    /// the regression "accuracy" score (1 − MAE / MAD). A constant
    /// predictor at the mean scores ~0; the paper's FCN plateaus ~0.73.
    pub fn y_mad(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let mean: f64 = self.y.iter().map(|&v| v as f64).sum::<f64>() / self.n as f64;
        self.y
            .iter()
            .map(|&v| (v as f64 - mean).abs())
            .sum::<f64>()
            / self.n as f64
    }
}

/// A dataset split into per-client partitions plus a held-out test set.
/// Partitions are index lists into `train` — data never moves between
/// clients (the FL privacy constraint is structural here).
#[derive(Clone, Debug)]
pub struct FederatedData {
    pub train: Dataset,
    pub test: Dataset,
    /// `partitions[k]` = the sample indices owned by client `k`.
    pub partitions: Vec<Vec<usize>>,
}

impl FederatedData {
    /// |D_k| per client.
    pub fn partition_sizes(&self) -> Vec<usize> {
        self.partitions.iter().map(|p| p.len()).collect()
    }

    /// |D^r| for a region given its client ids.
    pub fn region_data_size(&self, clients: &[usize]) -> usize {
        clients.iter().map(|&k| self.partitions[k].len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            x: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            y: vec![0.0, 2.0, 4.0],
            feature_dims: vec![2],
            n: 3,
        }
    }

    #[test]
    fn row_access() {
        let d = tiny();
        assert_eq!(d.feat_len(), 2);
        assert_eq!(d.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn mad_of_symmetric_targets() {
        let d = tiny();
        // mean=2, deviations |{-2,0,2}| -> mad = 4/3
        assert!((d.y_mad() - 4.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn federated_sizes() {
        let d = tiny();
        let fd = FederatedData {
            train: d.clone(),
            test: d,
            partitions: vec![vec![0], vec![1, 2]],
        };
        assert_eq!(fd.partition_sizes(), vec![1, 2]);
        assert_eq!(fd.region_data_size(&[0, 1]), 3);
    }
}
