//! Deterministic synthetic MNIST surrogate (Task 2).
//!
//! The real MNIST files are not available offline, so we generate a
//! 10-class 28×28 grayscale corpus with the properties the experiment
//! needs: classes are visually distinct structured patterns (LeNet-5
//! reaches >0.9 test accuracy, like on real MNIST), samples within a class
//! vary (jitter, amplitude, pixel noise) so the task is non-trivial, and
//! generation is deterministic per seed so Rust and the harness agree
//! byte-for-byte across runs.
//!
//! Each class prototype is a composition of 4–6 axis-aligned strokes
//! (rectangles) placed by a class-seeded RNG on the 28×28 canvas and then
//! box-blurred once — digit-like blobs without shipping any data.

use super::dataset::Dataset;
use crate::rng::Rng;

pub const HW: usize = 28;
pub const CLASSES: usize = 10;
const PIX: usize = HW * HW;

/// Build the 10 class prototypes for a corpus seed.
fn prototypes(seed: u64) -> Vec<[f32; PIX]> {
    (0..CLASSES)
        .map(|c| {
            let mut rng = Rng::new(seed ^ 0x5EED_1234 ^ ((c as u64) << 32));
            let mut img = [0.0f32; PIX];
            let strokes = 4 + rng.below(3); // 4..=6
            for _ in 0..strokes {
                // Stroke: either horizontal-ish or vertical-ish bar.
                let vertical = rng.bernoulli(0.5);
                let (w, h) = if vertical {
                    (2 + rng.below(3), 8 + rng.below(12))
                } else {
                    (8 + rng.below(12), 2 + rng.below(3))
                };
                let r0 = rng.below(HW - h.min(HW - 1));
                let c0 = rng.below(HW - w.min(HW - 1));
                let amp = 0.7 + 0.3 * rng.uniform();
                for r in r0..(r0 + h).min(HW) {
                    for cc in c0..(c0 + w).min(HW) {
                        img[r * HW + cc] = (img[r * HW + cc] + amp as f32).min(1.0);
                    }
                }
            }
            box_blur(&img)
        })
        .collect()
}

/// One 3×3 box blur pass (soft digit-like edges).
fn box_blur(img: &[f32; PIX]) -> [f32; PIX] {
    let mut out = [0.0f32; PIX];
    for r in 0..HW {
        for c in 0..HW {
            let mut sum = 0.0;
            let mut cnt = 0.0;
            for dr in -1i32..=1 {
                for dc in -1i32..=1 {
                    let rr = r as i32 + dr;
                    let cc = c as i32 + dc;
                    if (0..HW as i32).contains(&rr) && (0..HW as i32).contains(&cc) {
                        sum += img[rr as usize * HW + cc as usize];
                        cnt += 1.0;
                    }
                }
            }
            out[r * HW + c] = sum / cnt;
        }
    }
    out
}

/// Render one sample of class `label`: shifted (±2 px), amplitude-scaled
/// prototype plus pixel noise, clipped to [0, 1].
fn render(proto: &[f32; PIX], rng: &mut Rng) -> Vec<f32> {
    let dx = rng.below(5) as i32 - 2;
    let dy = rng.below(5) as i32 - 2;
    let amp = rng.normal_clamped(1.0, 0.15, 0.6, 1.4) as f32;
    let mut out = vec![0.0f32; PIX];
    for r in 0..HW as i32 {
        for c in 0..HW as i32 {
            let sr = r - dy;
            let sc = c - dx;
            let base = if (0..HW as i32).contains(&sr) && (0..HW as i32).contains(&sc) {
                proto[(sr * HW as i32 + sc) as usize]
            } else {
                0.0
            };
            let noise = rng.normal(0.0, 0.12) as f32;
            out[(r * HW as i32 + c) as usize] = (base * amp + noise).clamp(0.0, 1.0);
        }
    }
    out
}

/// Generate `n` samples with uniformly distributed labels.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let protos = prototypes(seed);
    let mut rng = Rng::new(seed ^ 0x3301_77AA);
    let mut x = Vec::with_capacity(n * PIX);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let label = rng.below(CLASSES);
        x.extend(render(&protos[label], &mut rng));
        y.push(label as f32);
    }
    Dataset {
        x,
        y,
        feature_dims: vec![1, HW, HW],
        n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_determinism() {
        let a = generate(50, 9);
        assert_eq!(a.n, 50);
        assert_eq!(a.x.len(), 50 * PIX);
        assert_eq!(a.feature_dims, vec![1, 28, 28]);
        let b = generate(50, 9);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn pixels_in_unit_range_and_labels_valid() {
        let d = generate(200, 4);
        assert!(d.x.iter().all(|&p| (0.0..=1.0).contains(&p)));
        assert!(d.y.iter().all(|&l| l >= 0.0 && l < 10.0 && l.fract() == 0.0));
    }

    #[test]
    fn all_classes_present() {
        let d = generate(500, 2);
        let mut seen = [false; 10];
        for &l in &d.y {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "seen={seen:?}");
    }

    #[test]
    fn classes_are_separated() {
        // Nearest-prototype classification on clean prototypes should be
        // perfect, and on noisy samples far better than chance — the
        // corpus must be learnable.
        let protos = prototypes(11);
        let d = generate(300, 11);
        let mut correct = 0;
        for i in 0..d.n {
            let row = d.row(i);
            let mut best = (f32::MAX, 0usize);
            for (c, p) in protos.iter().enumerate() {
                let dist: f32 = row
                    .iter()
                    .zip(p.iter())
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == d.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.n as f64;
        assert!(acc > 0.8, "nearest-prototype accuracy too low: {acc}");
    }

    #[test]
    fn intra_class_variation_exists() {
        let d = generate(100, 5);
        // Find two samples of the same class and check they differ.
        for i in 0..d.n {
            for j in (i + 1)..d.n {
                if d.y[i] == d.y[j] {
                    assert_ne!(d.row(i), d.row(j));
                    return;
                }
            }
        }
        panic!("no same-class pair found");
    }
}
