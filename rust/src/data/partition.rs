//! Client data partitioners — the paper's two data-distribution regimes.

use crate::config::Dist;
use crate::rng::Rng;

/// Task 1 regime: partition sizes drawn from 𝓝(μ, σ²) ("data distribution
/// 𝓝(100, 30²)"), clipped to ≥ `min_size`, then scaled so the disjoint
/// partitions exactly cover the `n_samples` corpus. Returns per-client
/// index lists over a shuffled corpus.
pub fn gaussian_partition(
    n_samples: usize,
    n_clients: usize,
    dist: Dist,
    min_size: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    assert!(n_clients > 0);
    // Draw raw sizes and normalize to the corpus size.
    let raw: Vec<f64> = (0..n_clients)
        .map(|_| rng.normal(dist.mean, dist.std).max(min_size as f64))
        .collect();
    let total: f64 = raw.iter().sum();
    let mut sizes: Vec<usize> = raw
        .iter()
        .map(|r| ((r / total) * n_samples as f64).floor() as usize)
        .collect();
    // Distribute the rounding remainder one sample at a time.
    let mut assigned: usize = sizes.iter().sum();
    let mut i = 0;
    while assigned < n_samples {
        sizes[i % n_clients] += 1;
        assigned += 1;
        i += 1;
    }
    // Hand out shuffled indices contiguously.
    let mut idx: Vec<usize> = (0..n_samples).collect();
    rng.shuffle(&mut idx);
    let mut out = Vec::with_capacity(n_clients);
    let mut cursor = 0;
    for &s in &sizes {
        out.push(idx[cursor..cursor + s].to_vec());
        cursor += s;
    }
    out
}

/// Task 2 regime: label-skewed non-IID. Sample `(x_i, y_i)` goes, with
/// probability `skew` (paper: 0.75), to a uniformly-chosen client whose
/// index is ≡ y_i (mod `n_classes`); otherwise to a uniformly-chosen
/// client. Mirrors the paper's "samples of class y_i assigned by
/// probability 0.75 to the clients with indices k ≡ y_i (mod 10)".
pub fn noniid_partition(
    labels: &[f32],
    n_clients: usize,
    n_classes: usize,
    skew: f64,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    assert!(n_clients > 0 && n_classes > 0);
    let mut out = vec![Vec::new(); n_clients];
    // Pre-index clients by (index mod n_classes) congruence class.
    let mut by_residue: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
    for k in 0..n_clients {
        by_residue[k % n_classes].push(k);
    }
    for (i, &label) in labels.iter().enumerate() {
        let y = (label as usize) % n_classes;
        let k = if rng.bernoulli(skew) && !by_residue[y].is_empty() {
            by_residue[y][rng.below(by_residue[y].len())]
        } else {
            rng.below(n_clients)
        };
        out[k].push(i);
    }
    out
}

/// Label-skew diagnostic: fraction of a client's samples whose label is
/// congruent to the client index. Used by tests and the data report.
pub fn skew_fraction(
    partitions: &[Vec<usize>],
    labels: &[f32],
    n_classes: usize,
) -> f64 {
    let mut matched = 0usize;
    let mut total = 0usize;
    for (k, part) in partitions.iter().enumerate() {
        for &i in part {
            if (labels[i] as usize) % n_classes == k % n_classes {
                matched += 1;
            }
            total += 1;
        }
    }
    if total == 0 {
        0.0
    } else {
        matched as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_covers_corpus_disjointly() {
        let mut rng = Rng::new(0);
        let parts = gaussian_partition(1503, 15, Dist::new(100.0, 30.0), 5, &mut rng);
        assert_eq!(parts.len(), 15);
        let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
        assert_eq!(all.len(), 1503);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 1503, "partitions overlap");
    }

    #[test]
    fn gaussian_sizes_vary() {
        let mut rng = Rng::new(1);
        let parts = gaussian_partition(1503, 15, Dist::new(100.0, 30.0), 5, &mut rng);
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        assert!(max > min, "sizes={sizes:?}");
        assert!(min >= 5);
    }

    #[test]
    fn noniid_covers_corpus_disjointly() {
        let mut rng = Rng::new(2);
        let labels: Vec<f32> = (0..5000).map(|i| (i % 10) as f32).collect();
        let parts = noniid_partition(&labels, 50, 10, 0.75, &mut rng);
        let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
        assert_eq!(all.len(), 5000);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 5000);
    }

    #[test]
    fn noniid_skew_is_strong() {
        let mut rng = Rng::new(3);
        let labels: Vec<f32> = (0..20_000).map(|i| (i % 10) as f32).collect();
        let parts = noniid_partition(&labels, 50, 10, 0.75, &mut rng);
        let skew = skew_fraction(&parts, &labels, 10);
        // 0.75 direct + 0.25 * (5/50 clients share the residue) ≈ 0.775
        assert!(skew > 0.7, "skew={skew}");
        // And an IID control is near 1/10... (5 clients per residue of 50)
        let iid = noniid_partition(&labels, 50, 10, 0.0, &mut rng);
        let skew_iid = skew_fraction(&iid, &labels, 10);
        assert!(skew_iid < 0.2, "iid skew={skew_iid}");
    }

    #[test]
    fn noniid_handles_more_classes_than_clients() {
        let mut rng = Rng::new(4);
        let labels: Vec<f32> = (0..100).map(|i| (i % 10) as f32).collect();
        let parts = noniid_partition(&labels, 3, 10, 0.75, &mut rng);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 100);
    }
}
