//! Datasets and federated partitioning (S12 in DESIGN.md).
//!
//! Builds the training corpus, the held-out evaluation set, and the
//! per-client partitions for either task from an [`ExperimentConfig`].

pub mod aerofoil;
pub mod dataset;
pub mod mnist_synth;
pub mod partition;

pub use dataset::{Dataset, FederatedData};

use crate::config::{ExperimentConfig, PartitionScheme, TaskKind};
use crate::rng::Rng;

/// Minimum partition size for the Gaussian-size scheme (a client with no
/// data cannot train).
const MIN_PARTITION: usize = 5;

/// Build the complete federated dataset for an experiment. Deterministic in
/// `cfg.seed`; the test set uses an independent RNG stream so changing
/// `eval_size` does not reshuffle training partitions.
pub fn build(cfg: &ExperimentConfig, rng: &mut Rng) -> FederatedData {
    let (train, test) = match cfg.task {
        TaskKind::Aerofoil => (
            aerofoil::generate(cfg.dataset_size, cfg.seed ^ 0xD474_0001),
            aerofoil::generate(cfg.eval_size, cfg.seed ^ 0xD474_0002),
        ),
        TaskKind::Mnist => {
            // mnist_synth derives class prototypes from the corpus seed, so
            // train and test must share it: generate one corpus and split.
            let all = mnist_synth::generate(
                cfg.dataset_size + cfg.eval_size,
                cfg.seed ^ 0xD474_0001,
            );
            split(all, cfg.dataset_size)
        }
    };

    let mut prng = rng.split(0x9A27);
    let partitions = match &cfg.partition {
        PartitionScheme::GaussianSize(d) => partition::gaussian_partition(
            train.n,
            cfg.n_clients,
            *d,
            MIN_PARTITION,
            &mut prng,
        ),
        PartitionScheme::NonIid { skew } => partition::noniid_partition(
            &train.y,
            cfg.n_clients,
            mnist_synth::CLASSES,
            *skew,
            &mut prng,
        ),
    };
    FederatedData {
        train,
        test,
        partitions,
    }
}

/// Split a dataset into (first `n_train`, rest).
fn split(all: Dataset, n_train: usize) -> (Dataset, Dataset) {
    let f = all.feat_len();
    let train = Dataset {
        x: all.x[..n_train * f].to_vec(),
        y: all.y[..n_train].to_vec(),
        feature_dims: all.feature_dims.clone(),
        n: n_train,
    };
    let test = Dataset {
        x: all.x[n_train * f..].to_vec(),
        y: all.y[n_train..].to_vec(),
        feature_dims: all.feature_dims,
        n: all.n - n_train,
    };
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_task1_covers_corpus() {
        let cfg = ExperimentConfig::task1_scaled();
        let mut rng = Rng::new(cfg.seed);
        let fd = build(&cfg, &mut rng);
        assert_eq!(fd.train.n, cfg.dataset_size);
        assert_eq!(fd.test.n, cfg.eval_size);
        assert_eq!(fd.partitions.len(), cfg.n_clients);
        assert_eq!(
            fd.partitions.iter().map(|p| p.len()).sum::<usize>(),
            cfg.dataset_size
        );
    }

    #[test]
    fn build_task2_shares_prototypes_across_split() {
        let mut cfg = ExperimentConfig::task2_scaled();
        cfg.dataset_size = 400;
        cfg.eval_size = 100;
        let mut rng = Rng::new(cfg.seed);
        let fd = build(&cfg, &mut rng);
        assert_eq!(fd.train.n, 400);
        assert_eq!(fd.test.n, 100);
        // Train/test must both contain all 10 classes (shared prototypes).
        for set in [&fd.train, &fd.test] {
            let mut seen = [false; 10];
            for &l in &set.y {
                seen[l as usize] = true;
            }
            assert!(seen.iter().filter(|&&s| s).count() >= 8);
        }
    }

    #[test]
    fn build_is_deterministic() {
        let cfg = ExperimentConfig::task1_scaled();
        let a = build(&cfg, &mut Rng::new(cfg.seed));
        let b = build(&cfg, &mut Rng::new(cfg.seed));
        assert_eq!(a.train.y, b.train.y);
        assert_eq!(a.partitions, b.partitions);
    }
}
