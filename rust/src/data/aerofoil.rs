//! Synthetic Aerofoil self-noise surrogate (Task 1).
//!
//! The paper trains on the UCI Airfoil Self-Noise set (1503 rows, 5
//! features: frequency, angle of attack, chord length, free-stream
//! velocity, suction-side displacement thickness; target: scaled sound
//! pressure level). That file is not available offline, so we generate a
//! surrogate with the same shape: 5 standardized features and a smooth
//! nonlinear response + irreducible noise, calibrated so a well-trained
//! FCN plateaus at a regression accuracy (1 − MAE/MAD) around the paper's
//! ≈0.727 best-accuracy scale (see DESIGN.md §Substitutions).

use super::dataset::Dataset;
use crate::rng::Rng;

/// Irreducible noise level on the standardized target. With a standard
/// normal-ish response, best-case accuracy ≈ 1 − noise_std ≈ 0.85; the
/// paper's 0.70 accuracy target then sits at ~82% of the plateau, a
/// comparable relative height to the paper's (0.70 of ~0.727).
const NOISE_STD: f64 = 0.15;

/// The smooth nonlinear response the FCN has to learn. Chosen to involve
/// every feature, saturating and interaction terms (the flavor of the
/// physical NASA airfoil response), and to be comfortably within reach of
/// a 5-64-32-1 tanh network.
fn response(f: &[f64; 5]) -> f64 {
    (std::f64::consts::PI * f[0] * 0.8).sin()
        + 0.6 * f[1] * f[1]
        - 0.4 * f[2] * f[3]
        + 0.9 * (1.2 * f[4]).tanh()
        + 0.3 * f[0] * f[4]
}

/// Generate `n` samples. Features are i.i.d. 𝓝(0,1); the target is
/// standardized to zero mean / unit variance over the generated set so the
/// MSE loss and the accuracy normalizer are scale-free.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xAE20_F011);
    let mut x = Vec::with_capacity(n * 5);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut f = [0.0f64; 5];
        for v in f.iter_mut() {
            *v = rng.gaussian();
        }
        let target = response(&f) + NOISE_STD * rng.gaussian();
        x.extend(f.iter().map(|&v| v as f32));
        y.push(target);
    }
    // Standardize the target.
    let mean = y.iter().sum::<f64>() / n.max(1) as f64;
    let var = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n.max(1) as f64;
    let std = var.sqrt().max(1e-9);
    let y: Vec<f32> = y.iter().map(|v| ((v - mean) / std) as f32).collect();
    Dataset {
        x,
        y,
        feature_dims: vec![5],
        n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_determinism() {
        let a = generate(100, 7);
        assert_eq!(a.n, 100);
        assert_eq!(a.x.len(), 500);
        assert_eq!(a.y.len(), 100);
        let b = generate(100, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = generate(100, 8);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn target_is_standardized() {
        let d = generate(2000, 1);
        let mean: f64 = d.y.iter().map(|&v| v as f64).sum::<f64>() / d.n as f64;
        let var: f64 =
            d.y.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / d.n as f64;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-6);
    }

    #[test]
    fn response_is_learnable_signal_dominant() {
        // Signal-to-noise: the nonlinear response must dominate the noise,
        // otherwise the task degenerates and accuracy saturates near 0.
        let d = generate(3000, 3);
        // MAD should be close to sqrt(2/pi) ~ 0.8 for a standardized,
        // near-Gaussian target.
        let mad = d.y_mad();
        assert!(mad > 0.6 && mad < 1.0, "mad={mad}");
    }
}
