//! Deterministic random number generation for the MEC simulator.
//!
//! The vendored dependency set has no `rand` crate, so we ship a small,
//! well-tested generator of our own: [`Rng`] is xoshiro256** seeded through
//! SplitMix64 (the construction recommended by the xoshiro authors), plus
//! the distributions the paper's experiment setup needs — uniform, Bernoulli,
//! Gaussian (Table II samples every heterogeneity parameter from a normal
//! distribution), partial Fisher–Yates selection (client sampling), and
//! stream splitting so each subsystem (device sampling, drop-out draws,
//! data partitioning, ...) gets an independent deterministic stream.

/// xoshiro256** PRNG. Deterministic, fast, and good enough statistically for
/// simulation workloads (not cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

/// Serializable generator state — what the checkpoint/replay subsystem
/// captures in a [`crate::snapshot::RunSnapshot`] so a resumed run draws
/// the exact sequence the interrupted run would have drawn. The cached
/// Box–Muller spare is part of the state: dropping it would desynchronize
/// every Gaussian stream by one variate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
        }
    }

    /// Snapshot the full generator state (checkpoint path).
    pub fn state(&self) -> RngState {
        RngState {
            s: self.s,
            gauss_spare: self.gauss_spare,
        }
    }

    /// Rebuild a generator from a captured state (resume path). The
    /// restored generator continues the original sequence exactly.
    pub fn from_state(state: RngState) -> Rng {
        Rng {
            s: state.s,
            gauss_spare: state.gauss_spare,
        }
    }

    /// Derive an independent child stream labelled by `stream`. Children of
    /// the same parent with different labels are uncorrelated; the parent is
    /// not advanced.
    pub fn split(&self, stream: u64) -> Rng {
        // Mix the current state with the label through SplitMix64.
        let mut seed = self.s[0] ^ self.s[2].rotate_left(17) ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        let _ = splitmix64(&mut seed);
        Rng::new(seed)
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's rejection-free-ish
    /// multiply-shift (bias negligible for simulation n's, but we reject to
    /// be exact).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (polar form), with spare caching.
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u = self.uniform_in(-1.0, 1.0);
            let v = self.uniform_in(-1.0, 1.0);
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with the given mean/stddev (Table II's 𝓝(μ, σ²) samplers).
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Normal clamped into `[lo, hi]` — used for probabilities and for
    /// physical quantities that must stay positive (perf, bandwidth, sizes).
    pub fn normal_clamped(&mut self, mean: f64, std: f64, lo: f64, hi: f64) -> f64 {
        self.normal(mean, std).clamp(lo, hi)
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct items from `0..n` (partial Fisher–Yates). The
    /// paper's client selection step draws `C_r(t) · n_r` clients uniformly
    /// without replacement.
    ///
    /// Dispatches between two byte-identical implementations: the dense
    /// materialized shuffle ([`Self::sample_indices_dense`]) and, when
    /// `k ≪ n`, a sparse O(k) variant ([`Self::sample_indices_sparse`])
    /// that never allocates the `0..n` array — at million-client fleet
    /// sizes the selection draw stops scaling with the fleet. Both consume
    /// the identical [`Self::below`] draws and return the identical
    /// output, so seeded runs do not depend on which one ran.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        // Crossover: the sparse path pays hashing per draw, the dense path
        // pays an O(n) allocation + writes. Well before k ~ n/8 the dense
        // path has amortized its allocation.
        if k.saturating_mul(8) < n {
            self.sample_indices_sparse(n, k)
        } else {
            self.sample_indices_dense(n, k)
        }
    }

    /// [`Self::sample_indices`], always via the materialized partial
    /// Fisher–Yates over an explicit `0..n` array. O(n) time and memory.
    pub fn sample_indices_dense(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// [`Self::sample_indices`] in O(k) time and memory: simulates the
    /// partial Fisher–Yates against a *virtual* identity array, recording
    /// only displaced entries in a hash map. Draw `i` swaps virtual
    /// positions `i` and `j = i + below(n−i)`; since every later draw
    /// reads positions `≥ i+1` only, it suffices to emit the value at `j`
    /// and stash the value displaced from `i` into `j`'s slot. The
    /// `below` draws — and therefore the output — are byte-identical to
    /// the dense variant for every `(state, n, k)`.
    pub fn sample_indices_sparse(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut displaced: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            let v_j = displaced.get(&j).copied().unwrap_or(j);
            let v_i = displaced.get(&i).copied().unwrap_or(i);
            displaced.insert(j, v_i);
            out.push(v_j);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_are_independent_and_stable() {
        let root = Rng::new(9);
        let mut c1 = root.split(1);
        let mut c1b = root.split(1);
        let mut c2 = root.split(2);
        assert_eq!(c1.next_u64(), c1b.next_u64());
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_uniform_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn normal_clamped_respects_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.normal_clamped(0.5, 10.0, 0.0, 1.0);
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(42);
        for _ in 0..100 {
            let k = r.below(20) + 1;
            let s = r.sample_indices(25, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in {s:?}");
            assert!(s.iter().all(|&i| i < 25));
        }
    }

    #[test]
    fn sample_indices_k_exceeding_n_caps() {
        let mut r = Rng::new(1);
        assert_eq!(r.sample_indices(4, 10).len(), 4);
        assert_eq!(r.sample_indices_sparse(4, 10).len(), 4);
    }

    #[test]
    fn sparse_sampling_is_byte_identical_to_dense() {
        // Lazy fate sampling leans on this: for every (seed, n, k) the
        // sparse simulation must consume the same `below` draws and emit
        // the same indices as the materialized shuffle, leaving the RNG
        // in the same state.
        for seed in [0u64, 7, 42, 0xDEAD_BEEF] {
            for &(n, k) in &[
                (1usize, 0usize),
                (1, 1),
                (25, 3),
                (25, 25),
                (100, 1),
                (100, 99),
                (1000, 8),
                (1000, 1000),
                (4, 10), // k > n caps at n
            ] {
                let mut dense = Rng::new(seed);
                let mut sparse = Rng::new(seed);
                assert_eq!(
                    dense.sample_indices_dense(n, k),
                    sparse.sample_indices_sparse(n, k),
                    "n={n} k={k} seed={seed}"
                );
                assert_eq!(dense.next_u64(), sparse.next_u64(), "post-state n={n} k={k}");
            }
        }
    }

    #[test]
    fn sample_indices_dispatch_matches_dense_across_threshold() {
        // The public entry point picks an implementation by k/n ratio;
        // both sides of the crossover must agree with the dense reference.
        for &(n, k) in &[(1000usize, 8usize), (1000, 200), (1000, 999)] {
            let mut a = Rng::new(99);
            let mut b = Rng::new(99);
            assert_eq!(a.sample_indices(n, k), b.sample_indices_dense(n, k));
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(17);
        let hits = (0..50_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 50_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    /// State capture/restore must continue the exact sequence, including
    /// across a pending Box–Muller spare.
    #[test]
    fn state_roundtrip_continues_sequence() {
        let mut r = Rng::new(31);
        for _ in 0..17 {
            r.next_u64();
        }
        let _ = r.gaussian(); // leaves a cached spare in the state
        let snap = r.state();
        assert!(snap.gauss_spare.is_some());
        let mut restored = Rng::from_state(snap);
        for _ in 0..5 {
            assert_eq!(restored.gaussian().to_bits(), r.gaussian().to_bits());
        }
        for _ in 0..100 {
            assert_eq!(restored.next_u64(), r.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(2);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
