//! Regional slack factor estimation — the paper's core §III.A mechanism.
//!
//! Each edge node (region) r keeps one [`SlackEstimator`]. At the start of
//! round t it yields the selection proportion
//!
//! ```text
//!     C_r(t) = C / θ̂_r(T)                                  (eq. 6)
//! ```
//!
//! where the slack factor θ̂ is fitted by least squares over the history of
//! *observable* quantities only (eq. 15):
//!
//! ```text
//!     θ̂_r(T) = (1/n_r) · Σᵢ C_r(i)·q_r(i)·|S_r(i)|  /  Σᵢ (C_r(i)·q_r(i))²
//! ```
//!
//! with `q_r(i) = |S_r(i)| / (C·n_r)` (eq. 12). `|S_r(i)|` — how many
//! models edge r collected in round i — is the **only** client-derived
//! input; the estimator never sees client identities, drop-out
//! probabilities, or aliveness, which is exactly the paper's
//! reliability-agnostic constraint (enforced here by the type signature:
//! `observe(submissions, quota_censored)`).
//!
//! ## Deviation from the literal equations (documented in DESIGN.md)
//!
//! Substituting eq. 12 into eq. 14 makes the regression degenerate: every
//! sample satisfies `y_i/x_i = C/C_r(i)` *identically* (both sides are
//! proportional to |S_r(i)|), so the LSE returns a weighted mean of the θ̂
//! values already used and the estimate can never leave its
//! initialization. The paper's own Fig. 2, however, shows θ̂ converging
//! near the regions' true reliability. We therefore split q_r by an
//! *observable* round attribute the cloud's aggregation signal already
//! carries — whether the round ended by quota or by deadline:
//!
//! * **Deadline round** (quota not met): every alive client had the full
//!   T_lim to submit, so the censoring factor q*_r = 1 by its definition
//!   (eq. 8) and `|S_r|/(C_r·n_r)` is an unbiased sample of θ_r. We set
//!   q̂ = 1.
//! * **Quota round** (censored): we keep eq. 12, clamped to ≤ 1 (q* is a
//!   fraction by definition).
//!
//! The resulting closed loop is self-correcting: an over-estimated θ̂
//! under-selects, misses the quota, produces deadline rounds whose
//! unbiased samples pull θ̂ down; over-delivery in quota rounds
//! (|S_r| > C·n_r) pushes θ̂ up. Equilibrium sits near the region's true
//! no-abort probability with E[|X_r|] ≈ C·n_r — exactly the paper's
//! selection target (eq. 1) and its Fig. 2 traces.
//!
//! The LSE numerator/denominator are kept as running sums, so each round
//! costs O(1) regardless of history length.

/// Public per-round snapshot (Fig. 2 traces).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SlackState {
    /// θ̂_r used for this round's selection.
    pub theta: f64,
    /// C_r(t) — the selection proportion actually applied.
    pub c_r: f64,
    /// q_r(t) observed at the end of the round (eq. 12).
    pub q_r: f64,
    /// |S_r(t)| observed at the end of the round.
    pub submissions: usize,
}

/// θ̂ is clamped into this range: a zero estimate would explode C_r; above
/// 1.0 is meaningless (cannot be more reliable than always-alive).
const THETA_MIN: f64 = 0.05;
const THETA_MAX: f64 = 1.0;

/// The estimator's complete mutable state, captured bit-for-bit for the
/// checkpoint/replay subsystem: the running LSE sums are what make θ̂ a
/// function of the whole submission history, so a resumed run must carry
/// them — re-seeding from `theta_init` would silently restart the
/// regression. Restored via [`SlackEstimator::from_state`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SlackEstimatorState {
    pub n_r: usize,
    pub c: f64,
    pub num: f64,
    pub den: f64,
    pub theta: f64,
    pub c_r: f64,
    pub last: Option<SlackState>,
    pub rounds_observed: usize,
}

#[derive(Clone, Debug)]
pub struct SlackEstimator {
    /// n_r — region population.
    n_r: usize,
    /// C — global desired proportion (set by the cloud).
    c: f64,
    /// Running Σ C_r(i)·q_r(i)·|S_r(i)|.
    num: f64,
    /// Running Σ (C_r(i)·q_r(i))².
    den: f64,
    /// θ̂ in effect for the upcoming round.
    theta: f64,
    /// C_r in effect for the upcoming round.
    c_r: f64,
    /// Last completed round's snapshot.
    last: Option<SlackState>,
    rounds_observed: usize,
}

impl SlackEstimator {
    /// `theta_init` seeds round 1 (paper uses 0.5); C_r(1) = C/θ_init.
    pub fn new(n_r: usize, c: f64, theta_init: f64) -> SlackEstimator {
        let theta = theta_init.clamp(THETA_MIN, THETA_MAX);
        SlackEstimator {
            n_r,
            c,
            num: 0.0,
            den: 0.0,
            theta,
            c_r: (c / theta).clamp(c, 1.0),
            last: None,
            rounds_observed: 0,
        }
    }

    /// C_r(t) for the upcoming round (eq. 6 / eq. 16), clamped into
    /// [C, 1]: a region can never select more than all of its clients, and
    /// selecting fewer than C·n_r could not possibly meet its share.
    pub fn c_r(&self) -> f64 {
        self.c_r
    }

    /// Number of clients to select: |U_r(t)| = C_r(t)·n_r, at least one.
    pub fn selection_count(&self) -> usize {
        ((self.c_r * self.n_r as f64).round() as usize)
            .clamp(1, self.n_r)
    }

    /// θ̂_r currently in effect.
    pub fn theta(&self) -> f64 {
        self.theta
    }

    /// End-of-round observation: |S_r(t)| — the number of models edge r
    /// collected before the cloud's aggregation signal — plus whether the
    /// round ended by quota (censored) or by deadline (uncensored). Both
    /// are cloud/edge-observable; no client state is probed. Updates the
    /// LSE sums and re-derives θ̂ and C_r for the next round.
    pub fn observe(&mut self, submissions: usize, quota_censored: bool) {
        let s = submissions as f64;
        // eq. 12 (clamped) in censored rounds; q* = 1 by definition in
        // deadline rounds — see the module docs on the degeneracy fix.
        let q = if quota_censored {
            (s / (self.c * self.n_r as f64)).min(1.0)
        } else {
            1.0
        };
        let cq = self.c_r * q;
        self.num += cq * s;
        self.den += cq * cq;
        self.rounds_observed += 1;
        self.last = Some(SlackState {
            theta: self.theta,
            c_r: self.c_r,
            q_r: q,
            submissions,
        });
        // eq. 15 — refit θ̂ (guard: all-zero history keeps the current θ̂).
        if self.den > 1e-12 {
            self.theta = (self.num / (self.n_r as f64 * self.den))
                .clamp(THETA_MIN, THETA_MAX);
        }
        // eq. 6/16 — next round's selection proportion.
        self.c_r = (self.c / self.theta).clamp(self.c, 1.0);
    }

    /// Snapshot of the last completed round (None before round 1 ends).
    pub fn last_state(&self) -> Option<SlackState> {
        self.last
    }

    /// Capture the full estimator state (checkpoint path).
    pub fn snapshot(&self) -> SlackEstimatorState {
        SlackEstimatorState {
            n_r: self.n_r,
            c: self.c,
            num: self.num,
            den: self.den,
            theta: self.theta,
            c_r: self.c_r,
            last: self.last,
            rounds_observed: self.rounds_observed,
        }
    }

    /// Rebuild an estimator from a captured state (resume path).
    pub fn from_state(state: SlackEstimatorState) -> SlackEstimator {
        SlackEstimator {
            n_r: state.n_r,
            c: state.c,
            num: state.num,
            den: state.den,
            theta: state.theta,
            c_r: state.c_r,
            last: state.last,
            rounds_observed: state.rounds_observed,
        }
    }

    pub fn rounds_observed(&self) -> usize {
        self.rounds_observed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn round_one_uses_theta_init() {
        let e = SlackEstimator::new(10, 0.3, 0.5);
        assert!((e.theta() - 0.5).abs() < 1e-12);
        assert!((e.c_r() - 0.6).abs() < 1e-12);
        assert_eq!(e.selection_count(), 6);
    }

    #[test]
    fn c_r_clamped_to_region() {
        // Tiny theta_init would give C_r > 1; must clamp.
        let e = SlackEstimator::new(10, 0.5, 0.1);
        assert!(e.c_r() <= 1.0);
        assert_eq!(e.selection_count(), 10);
    }

    #[test]
    fn zero_submission_history_keeps_theta() {
        let mut e = SlackEstimator::new(10, 0.3, 0.5);
        for _ in 0..5 {
            e.observe(0, true);
        }
        assert!((e.theta() - 0.5).abs() < 1e-12);
        assert_eq!(e.last_state().unwrap().q_r, 0.0);
    }

    /// Simulate the paper's steady-state: clients are alive w.p. p, the
    /// quota never censors (q* = 1, every alive client submits). θ̂ must
    /// converge near p so that C_r → C/p and E[|X_r|] → C·n_r — the
    /// selection target (eq. 1).
    #[test]
    fn theta_converges_to_reliability_when_uncensored() {
        let n_r = 40;
        let c = 0.3;
        let p = 0.6; // no-abort probability
        let mut e = SlackEstimator::new(n_r, c, 0.5);
        let mut rng = Rng::new(7);
        let mut alive_sum = 0.0;
        let rounds = 400;
        for t in 0..rounds {
            let selected = e.selection_count();
            let alive = (0..selected).filter(|_| rng.bernoulli(p)).count();
            if t >= rounds / 2 {
                alive_sum += alive as f64;
            }
            e.observe(alive, false);
        }
        let theta = e.theta();
        assert!(
            (theta - p).abs() < 0.08,
            "theta={theta} should approach reliability p={p}"
        );
        // Participation |X_r|/n_r should hover near C.
        let mean_alive = alive_sum / (rounds / 2) as f64 / n_r as f64;
        assert!(
            (mean_alive - c).abs() < 0.05,
            "mean alive fraction {mean_alive} should be near C={c}"
        );
    }

    /// With quota censoring (only a fraction q* of alive clients counted),
    /// θ̂ settles *below* the true reliability — the paper explicitly notes
    /// θ is "not necessarily equal to E[P_i]" (Fig. 2 converges to
    /// 0.46/0.63 for reliabilities 0.43/0.57).
    #[test]
    fn theta_reflects_censoring_not_just_reliability() {
        let n_r = 40;
        let c = 0.3;
        let p = 0.8;
        let q_star = 0.6;
        let mut uncensored = SlackEstimator::new(n_r, c, 0.5);
        let mut censored = SlackEstimator::new(n_r, c, 0.5);
        let mut rng = Rng::new(9);
        for _ in 0..300 {
            let s_u = uncensored.selection_count();
            let alive_u = (0..s_u).filter(|_| rng.bernoulli(p)).count();
            uncensored.observe(alive_u, false);

            let s_c = censored.selection_count();
            let alive_c = (0..s_c).filter(|_| rng.bernoulli(p)).count();
            censored.observe((alive_c as f64 * q_star).round() as usize, true);
        }
        assert!(
            censored.theta() < uncensored.theta(),
            "censoring must depress theta: {} !< {}",
            censored.theta(),
            uncensored.theta()
        );
    }

    #[test]
    fn selection_count_at_least_one() {
        let e = SlackEstimator::new(3, 0.05, 1.0);
        assert!(e.selection_count() >= 1);
    }

    /// A restored estimator must be indistinguishable from the original:
    /// same next selection, and identical θ̂ trajectory under identical
    /// future observations.
    #[test]
    fn snapshot_restore_preserves_trajectory() {
        let mut a = SlackEstimator::new(25, 0.3, 0.5);
        for t in 0..40 {
            a.observe(t % 9, t % 4 != 0);
        }
        let mut b = SlackEstimator::from_state(a.snapshot());
        assert_eq!(b.selection_count(), a.selection_count());
        assert_eq!(b.last_state(), a.last_state());
        for t in 0..40 {
            a.observe(t % 7, t % 3 == 0);
            b.observe(t % 7, t % 3 == 0);
            assert_eq!(a.theta().to_bits(), b.theta().to_bits());
            assert_eq!(a.c_r().to_bits(), b.c_r().to_bits());
        }
    }

    #[test]
    fn observe_updates_snapshot() {
        let mut e = SlackEstimator::new(10, 0.3, 0.5);
        e.observe(3, true);
        let s = e.last_state().unwrap();
        assert_eq!(s.submissions, 3);
        assert!((s.q_r - 1.0).abs() < 1e-12); // 3/(0.3*10)
        assert!((s.theta - 0.5).abs() < 1e-12);
        assert_eq!(e.rounds_observed(), 1);
    }
}
