//! Client selection (S1, paper §III.A): regional slack factors and the
//! probabilistic selection-proportion estimator.

pub mod slack;

pub use slack::{SlackEstimator, SlackEstimatorState};

use crate::rng::Rng;

/// Uniformly select `count` clients (without replacement) from a region's
/// client list — step 1 of every round, for every protocol.
pub fn select_clients(region_clients: &[usize], count: usize, rng: &mut Rng) -> Vec<usize> {
    rng.sample_indices(region_clients.len(), count)
        .into_iter()
        .map(|i| region_clients[i])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_from_region_without_replacement() {
        let clients = vec![10, 11, 12, 13, 14];
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let sel = select_clients(&clients, 3, &mut rng);
            assert_eq!(sel.len(), 3);
            let mut s = sel.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 3);
            assert!(sel.iter().all(|c| clients.contains(c)));
        }
    }

    #[test]
    fn count_capped_at_region_size() {
        let clients = vec![1, 2, 3];
        let mut rng = Rng::new(1);
        assert_eq!(select_clients(&clients, 10, &mut rng).len(), 3);
    }
}
