//! Client selection (S1, paper §III.A): the selection-strategy zoo.
//!
//! The paper's HybridFL picks *how many* clients per region with the
//! regional slack estimator ([`slack`]) and leaves *which* ones to a
//! uniform draw. This module generalizes both halves behind one
//! configuration knob ([`SelectorKind`], `ExperimentConfig::selector`):
//!
//! | selector | count head (how many)            | pick rule (which ones)      |
//! |----------|----------------------------------|-----------------------------|
//! | `slack`  | `C/θ̂_r` per region (eqs. 6/15)  | uniform without replacement |
//! | `fedcs`  | `C·n_r` per region               | fastest estimated round     |
//! |          |                                  | time first (FedCS-style)    |
//! | `oracle` | `C·n_r` per region               | ground-truth alive clients, |
//! |          |                                  | globally fastest first      |
//! | `random` | proportion ~ U[C, 1] per region  | uniform without replacement |
//!
//! The *count head* is protocol state: HybridFL owns one
//! [`SelectionStrategy`] (which for `slack` wraps the unchanged
//! [`SlackEstimator`]s — the default path is byte-identical to the
//! pre-zoo code). The *pick rule* is an environment concern — the
//! environment samples the concrete client set per the backend contract
//! — and is dispatched on `cfg.selector` inside `env::draw_selection`.
//! The baselines (FedAvg, HierFAVG) keep their own protocol-defined
//! counts, so for them a selector changes the pick rule only: `slack`
//! and `random` are both the uniform draw there.
//!
//! ## Why the oracle is sim-only
//!
//! [`SelectorKind::Oracle`] reads the round's ground-truth drop-out
//! fates *before* selection — information that exists only because the
//! virtual clock draws fates from a seeded table the environment can
//! peek at ahead of time. It deliberately violates the paper's
//! reliability-agnosticism constraint to measure the achievable optimum:
//! it selects only clients that will survive the round, globally fastest
//! first, so its round length is the theoretical floor every deployable
//! selector is compared against. A live cluster has no such table — the
//! future of a real device is not observable — so [`LiveClusterEnv`]
//! rejects `oracle` loudly at construction (like churn `Migrate`
//! events). Run oracle cells on the virtual clock.
//!
//! ## The evaluation matrix
//!
//! `harness::matrix` runs the scenario × protocol × selector grid (see
//! its docs for the adversarial churn compositions). Each cell reports
//! the mean round length (time-efficiency of the selection policy), the
//! converged best accuracy (whether aggressive selection starves
//! learning), the mean selected proportion (device burden: how many
//! clients the policy wakes per round), and the mean per-device energy
//! (what that burden costs). Reading a row against its `oracle` cell
//! shows how far the estimator sits from the optimum; reading it
//! against `random` shows what the estimator's knowledge is worth.
//!
//! [`LiveClusterEnv`]: crate::env::LiveClusterEnv

pub mod slack;

pub use slack::{SlackEstimator, SlackEstimatorState};

use anyhow::bail;

use crate::config::ExperimentConfig;
use crate::rng::Rng;
use crate::selection::slack::SlackState;
use crate::Result;

/// Which selection strategy a run uses (`--selector`, `--set selector=`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectorKind {
    /// The paper's regional slack estimator (default; byte-identical to
    /// the pre-zoo behavior).
    Slack,
    /// FedCS-style deadline-aware baseline: rank clients by the timing
    /// model's estimated completion time, fastest first.
    FedCs,
    /// Ground-truth upper bound: select only clients that will survive
    /// the round, globally fastest first. Sim-only.
    Oracle,
    /// Zero-knowledge control: a per-region selection proportion drawn
    /// uniformly from [C, 1] (the slack head's clamp band) each round,
    /// picked uniformly.
    Random,
}

impl SelectorKind {
    pub const ALL: [SelectorKind; 4] = [
        SelectorKind::Slack,
        SelectorKind::FedCs,
        SelectorKind::Oracle,
        SelectorKind::Random,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            SelectorKind::Slack => "slack",
            SelectorKind::FedCs => "fedcs",
            SelectorKind::Oracle => "oracle",
            SelectorKind::Random => "random",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "slack" => Ok(SelectorKind::Slack),
            "fedcs" => Ok(SelectorKind::FedCs),
            "oracle" => Ok(SelectorKind::Oracle),
            "random" => Ok(SelectorKind::Random),
            _ => bail!("unknown selector '{s}' (slack|fedcs|oracle|random)"),
        }
    }
}

/// Uniformly select `count` clients (without replacement) from a region's
/// client list — the pick rule of the `slack` and `random` selectors, for
/// every protocol.
///
/// Cost is O(count) when the draw is sparse relative to the region
/// (`Rng::sample_indices` dispatches to the hash-simulated Fisher–Yates),
/// so selecting a few hundred clients from a million-client region never
/// materializes the region-sized index pool. The draws are byte-identical
/// to the dense shuffle either way (pinned in `rng` and below).
pub fn select_clients(region_clients: &[usize], count: usize, rng: &mut Rng) -> Vec<usize> {
    rng.sample_indices(region_clients.len(), count)
        .into_iter()
        .map(|i| region_clients[i])
        .collect()
}

/// The count head of the selection zoo: how many clients HybridFL asks
/// for per region, and what protocol state that decision carries.
///
/// Implementations are deterministic in `(config, t, observation
/// history)` — no wall clock, no hidden RNG state — so a resumed run
/// re-derives the same counts. Only the slack head carries state across
/// rounds; the others snapshot an empty estimator list.
pub trait SelectionStrategy: Send {
    fn kind(&self) -> SelectorKind;

    /// |U_r(t)| per region for the upcoming round `t` (1-based).
    fn counts(&self, t: usize) -> Vec<usize>;

    /// End-of-round observation: per-region submission counts |S_r(t)|
    /// plus whether the round ended by quota (censored) or by deadline.
    /// Both are cloud/edge-observable; a stateless head ignores them.
    fn observe(&mut self, submissions: &[usize], quota_censored: bool);

    /// Per-region slack telemetry (Fig. 2 traces) — `Some` only for the
    /// slack head.
    fn slack_states(&self) -> Option<Vec<SlackState>>;

    /// Checkpointable state (empty for stateless heads).
    fn snapshot(&self) -> Vec<SlackEstimatorState>;

    /// Restore state captured by [`Self::snapshot`]. Errors on a shape
    /// mismatch instead of silently mixing two configurations.
    fn restore(&mut self, states: Vec<SlackEstimatorState>) -> Result<()>;
}

/// Instantiate the configured strategy for a topology with the given
/// per-region populations.
pub fn build_strategy(
    cfg: &ExperimentConfig,
    region_sizes: &[usize],
) -> Box<dyn SelectionStrategy> {
    match cfg.selector {
        SelectorKind::Slack => Box::new(SlackStrategy::new(cfg, region_sizes)),
        SelectorKind::FedCs | SelectorKind::Oracle => Box::new(FixedFractionStrategy {
            kind: cfg.selector,
            c: cfg.c_fraction,
            region_sizes: region_sizes.to_vec(),
        }),
        SelectorKind::Random => Box::new(RandomStrategy {
            seed: cfg.seed,
            c: cfg.c_fraction,
            region_sizes: region_sizes.to_vec(),
        }),
    }
}

/// Round a fractional selection proportion to a concrete count in
/// `[1, n_r]` (same rule as the slack head's `selection_count`).
fn fraction_count(fraction: f64, n: usize) -> usize {
    ((fraction * n as f64).round() as usize).clamp(1, n)
}

/// The paper's count head: one [`SlackEstimator`] per region, untouched
/// behind the trait — `counts` and `observe` call through to the exact
/// pre-zoo estimator code, so the default path is byte-identical.
pub struct SlackStrategy {
    estimators: Vec<SlackEstimator>,
}

impl SlackStrategy {
    pub fn new(cfg: &ExperimentConfig, region_sizes: &[usize]) -> SlackStrategy {
        SlackStrategy {
            estimators: region_sizes
                .iter()
                .map(|&n_r| SlackEstimator::new(n_r, cfg.c_fraction, cfg.theta_init))
                .collect(),
        }
    }
}

impl SelectionStrategy for SlackStrategy {
    fn kind(&self) -> SelectorKind {
        SelectorKind::Slack
    }

    fn counts(&self, _t: usize) -> Vec<usize> {
        self.estimators.iter().map(|s| s.selection_count()).collect()
    }

    fn observe(&mut self, submissions: &[usize], quota_censored: bool) {
        for (est, &s) in self.estimators.iter_mut().zip(submissions) {
            est.observe(s, quota_censored);
        }
    }

    fn slack_states(&self) -> Option<Vec<SlackState>> {
        Some(
            self.estimators
                .iter()
                .map(|s| {
                    s.last_state().unwrap_or(SlackState {
                        theta: s.theta(),
                        c_r: s.c_r(),
                        q_r: 0.0,
                        submissions: 0,
                    })
                })
                .collect(),
        )
    }

    fn snapshot(&self) -> Vec<SlackEstimatorState> {
        self.estimators.iter().map(|s| s.snapshot()).collect()
    }

    fn restore(&mut self, states: Vec<SlackEstimatorState>) -> Result<()> {
        anyhow::ensure!(
            states.len() == self.estimators.len(),
            "slack snapshot holds {} estimators but the topology has {} regions",
            states.len(),
            self.estimators.len()
        );
        self.estimators = states.into_iter().map(SlackEstimator::from_state).collect();
        Ok(())
    }
}

/// Stateless count head shared by `fedcs` and `oracle`: the target
/// participation `C·n_r` per region, every round. The interesting part
/// of both selectors is their pick rule, which lives in the environment.
struct FixedFractionStrategy {
    kind: SelectorKind,
    c: f64,
    region_sizes: Vec<usize>,
}

impl SelectionStrategy for FixedFractionStrategy {
    fn kind(&self) -> SelectorKind {
        self.kind
    }

    fn counts(&self, _t: usize) -> Vec<usize> {
        self.region_sizes
            .iter()
            .map(|&n| fraction_count(self.c, n))
            .collect()
    }

    fn observe(&mut self, _submissions: &[usize], _quota_censored: bool) {}

    fn slack_states(&self) -> Option<Vec<SlackState>> {
        None
    }

    fn snapshot(&self) -> Vec<SlackEstimatorState> {
        Vec::new()
    }

    fn restore(&mut self, states: Vec<SlackEstimatorState>) -> Result<()> {
        stateless_restore(self.kind, states)
    }
}

/// Label of the random count head's RNG stream, derived from the world
/// seed (disjoint from the `World::build` streams 1–5, and a pure
/// function of `(seed, t)` so resumed runs re-derive identical counts).
const SELECTOR_STREAM: u64 = 0x5E_1E_C7;

/// Zero-knowledge control head: each round, each region's selection
/// proportion is drawn uniformly from [C, 1] — the same band the slack
/// head's clamp confines `C_r` to. This is what "guessing inside the
/// feasible range" achieves; the learned estimator must beat it.
struct RandomStrategy {
    seed: u64,
    c: f64,
    region_sizes: Vec<usize>,
}

impl SelectionStrategy for RandomStrategy {
    fn kind(&self) -> SelectorKind {
        SelectorKind::Random
    }

    fn counts(&self, t: usize) -> Vec<usize> {
        let mut rng = Rng::new(self.seed).split(SELECTOR_STREAM).split(t as u64);
        self.region_sizes
            .iter()
            .map(|&n| fraction_count(rng.uniform_in(self.c, 1.0), n))
            .collect()
    }

    fn observe(&mut self, _submissions: &[usize], _quota_censored: bool) {}

    fn slack_states(&self) -> Option<Vec<SlackState>> {
        None
    }

    fn snapshot(&self) -> Vec<SlackEstimatorState> {
        Vec::new()
    }

    fn restore(&mut self, states: Vec<SlackEstimatorState>) -> Result<()> {
        stateless_restore(SelectorKind::Random, states)
    }
}

fn stateless_restore(kind: SelectorKind, states: Vec<SlackEstimatorState>) -> Result<()> {
    anyhow::ensure!(
        states.is_empty(),
        "snapshot carries {} slack estimators but the '{}' selector is stateless \
         (was the snapshot taken under a different selector?)",
        states.len(),
        kind.as_str()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_from_region_without_replacement() {
        let clients = vec![10, 11, 12, 13, 14];
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let sel = select_clients(&clients, 3, &mut rng);
            assert_eq!(sel.len(), 3);
            let mut s = sel.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 3);
            assert!(sel.iter().all(|c| clients.contains(c)));
        }
    }

    /// A sparse draw (few clients from a huge region) must pick the exact
    /// clients the dense reference implementation would — the selection
    /// layer's half of the lazy-sampling byte-identity pin.
    #[test]
    fn sparse_region_draw_matches_dense_reference() {
        let clients: Vec<usize> = (0..100_000).map(|k| k * 2 + 1).collect();
        for seed in [0u64, 9, 77] {
            let sel = select_clients(&clients, 40, &mut Rng::new(seed));
            let dense: Vec<usize> = Rng::new(seed)
                .sample_indices_dense(clients.len(), 40)
                .into_iter()
                .map(|i| clients[i])
                .collect();
            assert_eq!(sel, dense, "seed {seed}");
        }
    }

    #[test]
    fn count_capped_at_region_size() {
        let clients = vec![1, 2, 3];
        let mut rng = Rng::new(1);
        assert_eq!(select_clients(&clients, 10, &mut rng).len(), 3);
    }

    #[test]
    fn selector_kind_parse_roundtrip() {
        for k in SelectorKind::ALL {
            assert_eq!(SelectorKind::parse(k.as_str()).unwrap(), k);
        }
        let err = SelectorKind::parse("psychic").unwrap_err().to_string();
        assert!(err.contains("psychic") && err.contains("oracle"), "{err}");
    }

    fn cfg_with(selector: SelectorKind) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.selector = selector;
        cfg
    }

    #[test]
    fn build_strategy_matches_config_kind() {
        for k in SelectorKind::ALL {
            let s = build_strategy(&cfg_with(k), &[10, 10]);
            assert_eq!(s.kind(), k);
        }
    }

    /// The slack head behind the trait must compute the exact counts the
    /// bare estimators would — the byte-identity hinge.
    #[test]
    fn slack_strategy_mirrors_bare_estimators() {
        let cfg = cfg_with(SelectorKind::Slack);
        let sizes = [12usize, 8];
        let mut strat = SlackStrategy::new(&cfg, &sizes);
        let mut bare: Vec<SlackEstimator> = sizes
            .iter()
            .map(|&n| SlackEstimator::new(n, cfg.c_fraction, cfg.theta_init))
            .collect();
        for t in 1..=30 {
            let want: Vec<usize> = bare.iter().map(|e| e.selection_count()).collect();
            assert_eq!(strat.counts(t), want, "round {t}");
            let subs = [t % 5, (t * 3) % 4];
            let censored = t % 3 != 0;
            strat.observe(&subs, censored);
            for (e, &s) in bare.iter_mut().zip(&subs) {
                e.observe(s, censored);
            }
        }
        // And the snapshots are the estimators' own snapshots.
        let snap = strat.snapshot();
        for (s, e) in snap.iter().zip(&bare) {
            assert_eq!(*s, e.snapshot());
        }
    }

    #[test]
    fn fixed_fraction_counts_hit_target_participation() {
        let s = build_strategy(&cfg_with(SelectorKind::FedCs), &[10, 7, 1]);
        assert_eq!(s.counts(1), vec![3, 2, 1]); // 0.3 · n_r, floored at 1
        assert_eq!(s.counts(99), s.counts(1)); // stateless: same every round
        let o = build_strategy(&cfg_with(SelectorKind::Oracle), &[10, 7, 1]);
        assert_eq!(o.counts(5), vec![3, 2, 1]);
    }

    #[test]
    fn random_counts_stay_in_clamp_band_and_are_reproducible() {
        let cfg = cfg_with(SelectorKind::Random);
        let s = build_strategy(&cfg, &[20, 20]);
        let again = build_strategy(&cfg, &[20, 20]);
        let mut saw_above_c = false;
        for t in 1..=50 {
            let counts = s.counts(t);
            assert_eq!(counts, again.counts(t), "pure function of (seed, t)");
            for &c in &counts {
                // proportion ∈ [C, 1] ⇒ count ∈ [C·n_r rounded, n_r]
                assert!((6..=20).contains(&c), "round {t}: count {c}");
                if c > 6 {
                    saw_above_c = true;
                }
            }
        }
        assert!(saw_above_c, "the control should explore above C");
        // A different seed explores a different trajectory.
        let mut other_cfg = cfg_with(SelectorKind::Random);
        other_cfg.seed = cfg.seed + 1;
        let other = build_strategy(&other_cfg, &[20, 20]);
        let diverged = (1..=50).any(|t| other.counts(t) != s.counts(t));
        assert!(diverged);
    }

    #[test]
    fn stateless_heads_reject_slack_snapshots() {
        let mut s = build_strategy(&cfg_with(SelectorKind::FedCs), &[10]);
        assert!(s.snapshot().is_empty());
        assert!(s.restore(Vec::new()).is_ok());
        let est = SlackEstimator::new(10, 0.3, 0.5);
        let err = s.restore(vec![est.snapshot()]).unwrap_err().to_string();
        assert!(err.contains("stateless"), "{err}");
    }

    #[test]
    fn slack_strategy_restore_checks_region_count() {
        let cfg = cfg_with(SelectorKind::Slack);
        let mut s = SlackStrategy::new(&cfg, &[10, 10]);
        let err = s.restore(Vec::new()).unwrap_err().to_string();
        assert!(err.contains("2 regions"), "{err}");
    }
}
