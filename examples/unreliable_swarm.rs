//! Unreliable swarm, now with a *churning* world: watch the regional
//! slack factors adapt, live, to reliability that the protocol cannot
//! observe — and that refuses to stand still.
//!
//! Three regions with drop-out means 0.2 / 0.5 / 0.8, two churn layers
//! on top of the sampled fleet:
//!
//! * **MarkovOnOff** — every client is a bursty two-state chain, so
//!   outages arrive correlated over rounds instead of i.i.d.;
//! * **FaultScript** — one scripted blackout takes region 2's edge down
//!   completely for rounds 50..70.
//!
//! The edges still only count submissions — no client probing — yet θ̂_r
//! separates by reliability, collapses with the blackout, and re-converges
//! after the edge comes back. The run's ground truth (per-round fates) is
//! exported to a replayable JSON trace at the end.
//!
//! ```bash
//! cargo run --release --example unreliable_swarm     # mock engine, instant
//! ```

use hybridfl::churn::{ChurnModel, FaultEvent};
use hybridfl::config::{Dist, RegionSpec};
use hybridfl::scenario::Scenario;

fn main() -> hybridfl::Result<()> {
    let blackout = FaultEvent::RegionBlackout {
        region: 2,
        from_round: 50,
        until_round: 70,
    };
    let sc = Scenario::task1()
        .mock() // protocol dynamics; no artifacts needed
        .clients(60)
        .edges(3)
        .dataset_size(3000)
        .c_fraction(0.3)
        .rounds(140)
        .tune(|cfg| {
            cfg.name = "unreliable-swarm".into();
            cfg.regions = vec![
                RegionSpec { n_clients: 20, dropout_mean: 0.2 },
                RegionSpec { n_clients: 20, dropout_mean: 0.5 },
                RegionSpec { n_clients: 20, dropout_mean: 0.8 },
            ];
            cfg.dropout = Dist::new(0.5, 0.05);
        })
        .churn(ChurnModel::Composed {
            layers: vec![
                ChurnModel::MarkovOnOff {
                    p_fail: 0.05,
                    p_recover: 0.3,
                    down_dropout: 0.95,
                    region_scale: Vec::new(),
                },
                ChurnModel::FaultScript {
                    events: vec![blackout],
                },
            ],
        })
        .record_fates("reports/unreliable_swarm_fates.json");

    println!("three regions, drop-out means 0.2 / 0.5 / 0.8 — reliability agnostic");
    println!("churn: markov bursts everywhere + region 3 blackout over rounds 50..70");
    println!(
        "cloud target: C = {} of the fleet submitting each round\n",
        sc.config().c_fraction
    );

    let result = sc.run()?;

    println!("round |        theta_r        |      avail_r (truth)   |   |X_r|/n_r");
    for row in result
        .rounds
        .iter()
        .filter(|r| r.t % 10 == 0 || r.t == 1 || r.t == 50 || r.t == 70)
    {
        let slack = row.slack.as_ref().unwrap();
        let thetas: Vec<String> = slack.iter().map(|s| format!("{:.2}", s.theta)).collect();
        let avail: Vec<String> = row.avail.iter().map(|a| format!("{a:.2}")).collect();
        let alive: Vec<String> = row
            .alive
            .iter()
            .map(|&a| format!("{:.2}", a as f64 / 20.0))
            .collect();
        println!(
            "{:>5} | {:>21} | {:>22} | {:>16}",
            row.t,
            thetas.join("  "),
            avail.join("  "),
            alive.join("  ")
        );
    }

    // The blackout window: region 3 goes silent, ground truth says why.
    let in_blackout = &result.rounds[54]; // t = 55
    println!(
        "\nmid-blackout (round {}): region 3 avail {:.2}, submissions {:?}",
        in_blackout.t, in_blackout.avail[2], in_blackout.submissions
    );
    assert_eq!(in_blackout.submissions[2], 0);

    // Converged view after the blackout lifts (last 30 rounds).
    let tail = &result.rounds[110..];
    println!("\nre-converged means (rounds 111-140, blackout long over):");
    for r in 0..3 {
        let theta: f64 =
            tail.iter().map(|x| x.slack.as_ref().unwrap()[r].theta).sum::<f64>() / 30.0;
        let alive: f64 =
            tail.iter().map(|x| x.alive[r] as f64 / 20.0).sum::<f64>() / 30.0;
        let avail: f64 = tail.iter().map(|x| x.avail[r]).sum::<f64>() / 30.0;
        println!(
            "  region {} (E[dr]={:.1}):  theta={theta:.2}  truth avail={avail:.2}  \
             participation={alive:.2}  (target C=0.30)",
            r + 1,
            [0.2, 0.5, 0.8][r]
        );
    }
    println!("\nground-truth fate trace -> reports/unreliable_swarm_fates.json");
    println!("replay it by rebuilding this scenario with");
    println!("  .replay_fates(\"reports/unreliable_swarm_fates.json\")");
    println!("in place of .churn(..) — same rounds, fate for fate.");
    Ok(())
}
