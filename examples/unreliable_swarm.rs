//! Unreliable swarm: watch the regional slack factors adapt, live, to a
//! fleet whose regions have wildly different (and agnostic!) reliability.
//!
//! Three regions with drop-out means 0.2 / 0.5 / 0.8. The edges can only
//! count submissions — no client probing — yet θ̂_r separates cleanly and
//! per-region participation |X_r|/n_r is steered toward the cloud's C.
//!
//! ```bash
//! cargo run --release --example unreliable_swarm     # mock engine, instant
//! ```

use hybridfl::config::{Dist, RegionSpec};
use hybridfl::scenario::Scenario;

fn main() -> hybridfl::Result<()> {
    let sc = Scenario::task1()
        .mock() // protocol dynamics; no artifacts needed
        .clients(60)
        .edges(3)
        .dataset_size(3000)
        .c_fraction(0.3)
        .rounds(120)
        .tune(|cfg| {
            cfg.name = "unreliable-swarm".into();
            cfg.regions = vec![
                RegionSpec { n_clients: 20, dropout_mean: 0.2 },
                RegionSpec { n_clients: 20, dropout_mean: 0.5 },
                RegionSpec { n_clients: 20, dropout_mean: 0.8 },
            ];
            cfg.dropout = Dist::new(0.5, 0.05);
        });

    println!("three regions, drop-out means 0.2 / 0.5 / 0.8 — reliability agnostic");
    println!(
        "cloud target: C = {} of the fleet submitting each round\n",
        sc.config().c_fraction
    );

    let result = sc.run()?;

    println!("round |        theta_r        |         C_r          |   |X_r|/n_r");
    for row in result.rounds.iter().filter(|r| r.t % 12 == 0 || r.t == 1) {
        let slack = row.slack.as_ref().unwrap();
        let thetas: Vec<String> = slack.iter().map(|s| format!("{:.2}", s.theta)).collect();
        let crs: Vec<String> = slack.iter().map(|s| format!("{:.2}", s.c_r)).collect();
        let alive: Vec<String> = row
            .alive
            .iter()
            .map(|&a| format!("{:.2}", a as f64 / 20.0))
            .collect();
        println!(
            "{:>5} | {:>21} | {:>20} | {:>16}",
            row.t,
            thetas.join("  "),
            crs.join("  "),
            alive.join("  ")
        );
    }

    // Converged view (last 30 rounds).
    let tail = &result.rounds[90..];
    println!("\nconverged means (rounds 91-120):");
    for r in 0..3 {
        let theta: f64 =
            tail.iter().map(|x| x.slack.as_ref().unwrap()[r].theta).sum::<f64>() / 30.0;
        let alive: f64 =
            tail.iter().map(|x| x.alive[r] as f64 / 20.0).sum::<f64>() / 30.0;
        println!(
            "  region {} (E[dr]={:.1}):  theta={theta:.2}  participation={alive:.2}  (target C=0.30)",
            r + 1,
            [0.2, 0.5, 0.8][r]
        );
    }
    Ok(())
}
