//! End-to-end driver (the repo's full-stack validation run): federated
//! LeNet-5 training on the non-IID synthetic MNIST corpus, all three
//! protocols compared under identical seeds. Real PJRT execution of the
//! AOT JAX/Pallas artifacts when available, mock dynamics otherwise.
//!
//! With artifacts this exercises every layer at once: L1 Pallas kernels
//! (inside the lowered HLO), L2 LeNet train/eval graphs, L3 coordinator
//! (slack selection, quota trigger, EDC aggregation), the MEC
//! timing/energy simulator, and the metrics stack. The loss/accuracy
//! curves land in `reports/e2e_mnist_<protocol>.csv`; the run is recorded
//! in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example mnist_noniid_e2e          # ~4 min on 1 core
//! ```

use hybridfl::config::ProtocolKind;
use hybridfl::metrics;
use hybridfl::scenario::Scenario;

fn main() -> hybridfl::Result<()> {
    let out_dir = std::path::Path::new("reports");
    std::fs::create_dir_all(out_dir)?;
    let have_pjrt = hybridfl::runtime::pjrt_available();
    if !have_pjrt {
        eprintln!("(PJRT unavailable — missing artifacts or the `pjrt` feature; using the mock engine)");
    }

    println!("=== E2E: federated LeNet-5 on non-IID synthetic MNIST ===");
    println!("50 clients / 5 edges / 2.5k samples (0.75 label skew), E[dr]=0.3\n");

    let mut wins: Vec<(String, f64, f64, f64)> = Vec::new();
    for proto in ProtocolKind::ALL {
        let mut sc = Scenario::task2().protocol(proto).rounds(50).dropout(0.3);
        if !have_pjrt {
            sc = sc.mock();
        }

        eprintln!("[{}] training...", proto.as_str());
        let schema = metrics::CsvSchema::from_config(sc.config());
        let result = sc.run()?;

        println!("--- {} ---", proto.as_str());
        println!(" round |   loss   | accuracy | cum time (s)");
        for row in result.rounds.iter().filter(|r| r.t % 10 == 0 || r.t == 1) {
            println!(
                " {:>5} | {:>8.4} | {:>8.3} | {:>12.1}",
                row.t, row.eval_loss, row.accuracy, row.cum_time
            );
        }
        let s = &result.summary;
        println!(
            " => best acc {:.3}, avg round {:.1}s, energy {:.4} Wh/device\n",
            s.best_accuracy, s.avg_round_len, s.mean_device_energy_wh
        );
        metrics::write_csv_with(
            &out_dir.join(format!("e2e_mnist_{}.csv", proto.as_str())),
            &schema,
            &result.rounds,
        )?;
        wins.push((
            proto.as_str().to_string(),
            s.best_accuracy,
            s.total_time,
            s.mean_device_energy_wh,
        ));
    }

    println!("=== summary (identical seeds, 50 rounds) ===");
    println!("{:<10} {:>9} {:>14} {:>12}", "protocol", "best acc", "total time (s)", "Wh/device");
    for (name, acc, time, wh) in &wins {
        println!("{name:<10} {acc:>9.3} {time:>14.1} {wh:>12.4}");
    }
    println!("\ncurves -> reports/e2e_mnist_<protocol>.csv");
    Ok(())
}
