//! Live cluster demo: the HybridFL coordination as a *real* concurrent
//! system — 1 cloud thread + 4 edge threads + 40 client threads over mpsc
//! channels, quota-vs-deadline arbitration in wall-clock time.
//!
//! Exactly the same protocol implementation that runs on the virtual
//! clock: only `.backend(Backend::Live)` changes.
//!
//! ```bash
//! cargo run --release --example live_cluster
//! ```

use hybridfl::config::Dist;
use hybridfl::scenario::{Backend, Scenario};

fn main() -> hybridfl::Result<()> {
    let sc = Scenario::task1()
        .mock()
        .clients(40)
        .edges(4)
        .dataset_size(2000)
        .tune(|cfg| cfg.dropout = Dist::new(0.3, 0.05))
        .rounds(12)
        .backend(Backend::Live)
        .time_scale(1e-4);

    let cfg = sc.config();
    println!(
        "spawning live cluster: 1 cloud + {} edges + {} clients (threads)",
        cfg.n_edges, cfg.n_clients
    );
    println!("virtual time scaled 1e-4 (a ~90 s round plays out in ~9 ms)\n");

    let result = sc.run()?;

    println!("round | round len (s) | per-region submissions | quota met | accuracy");
    for s in &result.rounds {
        println!(
            "{:>5} | {:>13.1} | {:>22} | {:>9} | {:>8.3}",
            s.t,
            s.round_len,
            format!("{:?}", s.submissions),
            !s.deadline_hit,
            s.accuracy
        );
    }

    let met = result.rounds.iter().filter(|s| !s.deadline_hit).count();
    println!(
        "\n{met}/{} rounds ended by quota (rest by deadline); \
         global model advanced every round the quota flowed.",
        result.rounds.len()
    );
    Ok(())
}
