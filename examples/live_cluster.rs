//! Live cluster demo: the HybridFL coordination as a *real* concurrent
//! system — 1 cloud thread + 4 edge threads + 40 client threads over mpsc
//! channels, quota-vs-deadline arbitration in wall-clock time.
//!
//! ```bash
//! cargo run --release --example live_cluster
//! ```

use hybridfl::config::{Dist, ExperimentConfig};
use hybridfl::live::{LiveCluster, LiveOpts};

fn main() -> hybridfl::Result<()> {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.n_clients = 40;
    cfg.n_edges = 4;
    cfg.dataset_size = 2000;
    cfg.dropout = Dist::new(0.3, 0.05);

    println!(
        "spawning live cluster: 1 cloud + {} edges + {} clients (threads)",
        cfg.n_edges, cfg.n_clients
    );
    println!("virtual time scaled 1e-4 (a ~90 s round plays out in ~9 ms)\n");

    let cluster = LiveCluster::new(cfg)?;
    let stats = cluster.run(&LiveOpts { rounds: 12, time_scale: 1e-4 })?;

    println!("round |   wall   | per-region submissions | quota met | progress");
    for s in &stats {
        println!(
            "{:>5} | {:>8.1?} | {:>23} | {:>9} | {:>8.2}",
            s.t,
            s.wall,
            format!("{:?}", s.submissions),
            s.quota_met,
            s.global_progress
        );
    }

    let met = stats.iter().filter(|s| s.quota_met).count();
    println!(
        "\n{met}/{} rounds ended by quota (rest by deadline); \
         global model advanced every round the quota flowed.",
        stats.len()
    );
    Ok(())
}
