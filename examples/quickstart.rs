//! Quickstart: run HybridFL on the Aerofoil task for 60 rounds with real
//! PJRT training and print what happened.
//!
//! ```bash
//! make artifacts            # once: AOT-compile the JAX/Pallas models
//! cargo run --release --example quickstart
//! ```

use hybridfl::config::ExperimentConfig;
use hybridfl::sim::FlRun;

fn main() -> hybridfl::Result<()> {
    // Start from the scaled Task-1 preset (15 clients, 3 edge nodes) and
    // dial in a short demo run under moderate unreliability.
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.t_max = 60;
    cfg.dropout.mean = 0.3; // 30% of clients drop out of any given round
    cfg.c_fraction = 0.3; //   the cloud wants models from 30% per round

    println!(
        "HybridFL quickstart: {} clients / {} edges, E[dr]={}, C={}",
        cfg.n_clients, cfg.n_edges, cfg.dropout.mean, cfg.c_fraction
    );

    let result = FlRun::new(cfg)?.run()?;

    // Accuracy trace, ten-round granularity.
    println!("\n round | accuracy | round len (s) | submissions");
    for row in result.rounds.iter().filter(|r| r.t % 10 == 0) {
        println!(
            " {:>5} | {:>8.3} | {:>13.1} | {:?}",
            row.t,
            row.accuracy,
            row.round_len,
            row.submissions
        );
    }

    let s = &result.summary;
    println!("\nbest accuracy        : {:.3}", s.best_accuracy);
    println!("avg federated round  : {:.1} s (virtual)", s.avg_round_len);
    println!("mean device energy   : {:.4} Wh", s.mean_device_energy_wh);
    Ok(())
}
