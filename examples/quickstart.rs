//! Quickstart: run HybridFL on the Aerofoil task for 60 rounds and print
//! what happened. Uses real PJRT training when the AOT artifacts are
//! present (`make artifacts` + `--features pjrt`), otherwise falls back to
//! the analytic mock engine so the demo always runs.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hybridfl::scenario::Scenario;

fn main() -> hybridfl::Result<()> {
    // Scaled Task-1 preset (15 clients, 3 edge nodes), dialed to a short
    // demo run under moderate unreliability.
    let mut sc = Scenario::task1()
        .rounds(60)
        .dropout(0.3) // 30% of clients drop out of any given round
        .c_fraction(0.3); // the cloud wants models from 30% per round

    if !hybridfl::runtime::pjrt_available() {
        eprintln!("(PJRT unavailable — missing artifacts or the `pjrt` feature; using the mock engine)");
        sc = sc.mock();
    }

    let cfg = sc.config();
    println!(
        "HybridFL quickstart: {} clients / {} edges, E[dr]={}, C={}",
        cfg.n_clients, cfg.n_edges, cfg.dropout.mean, cfg.c_fraction
    );

    let result = sc.run()?;

    // Accuracy trace, ten-round granularity.
    println!("\n round | accuracy | round len (s) | submissions");
    for row in result.rounds.iter().filter(|r| r.t % 10 == 0) {
        println!(
            " {:>5} | {:>8.3} | {:>13.1} | {:?}",
            row.t, row.accuracy, row.round_len, row.submissions
        );
    }

    let s = &result.summary;
    println!("\nbest accuracy        : {:.3}", s.best_accuracy);
    println!("avg federated round  : {:.1} s (virtual)", s.avg_round_len);
    println!("mean device energy   : {:.4} Wh", s.mean_device_energy_wh);
    Ok(())
}
