//! Device-energy report (the Figs. 5/7 story): how much battery does each
//! protocol burn to reach the same model quality on unreliable clients?
//!
//! Runs all three protocols on the Aerofoil task at E[dr] = 0.6, then
//! reports mean on-device Wh at the accuracy-target crossing — the metric
//! the paper argues decides whether device owners keep participating.
//! Real PJRT training when the artifacts are present, mock otherwise.
//!
//! ```bash
//! make artifacts            # optional, for real training
//! cargo run --release --example energy_report
//! ```

use hybridfl::config::ProtocolKind;
use hybridfl::scenario::Scenario;

const TARGET: f64 = 0.65;

fn main() -> hybridfl::Result<()> {
    let have_pjrt = hybridfl::runtime::pjrt_available();
    if !have_pjrt {
        eprintln!("(PJRT unavailable — missing artifacts or the `pjrt` feature; using the mock engine)");
    }
    println!("energy to reach accuracy {TARGET} — Aerofoil, E[dr]=0.6, C=0.3\n");
    println!(
        "{:<10} {:>9} {:>9} {:>12} {:>13} {:>12}",
        "protocol", "best acc", "rounds", "time (s)", "Wh/device", "vs hybridfl"
    );

    let mut rows: Vec<(String, f64, Option<usize>, Option<f64>, f64)> = Vec::new();
    for proto in ProtocolKind::ALL {
        let mut sc = Scenario::task1().protocol(proto).dropout(0.6);
        if !have_pjrt {
            sc = sc.mock();
        }
        let n_clients = sc.config().n_clients as f64;
        let result = sc.run()?;

        // Energy at the target crossing (end of run if never crossed).
        let crossing = result.rounds.iter().find(|r| r.best_accuracy >= TARGET);
        let (rounds, time, energy_j) = match crossing {
            Some(row) => (Some(row.t), Some(row.cum_time), row.cum_energy_j),
            None => (
                None,
                None,
                result.rounds.last().map_or(0.0, |r| r.cum_energy_j),
            ),
        };
        rows.push((
            proto.as_str().to_string(),
            result.summary.best_accuracy,
            rounds,
            time,
            energy_j / 3600.0 / n_clients,
        ));
    }

    let hybrid_wh = rows.last().map(|r| r.4).unwrap_or(1.0);
    for (name, acc, rounds, time, wh) in &rows {
        println!(
            "{:<10} {:>9.3} {:>9} {:>12} {:>13.4} {:>11.2}x",
            name,
            acc,
            rounds.map_or("-".into(), |r| r.to_string()),
            time.map_or("-".into(), |t| format!("{t:.0}")),
            wh,
            wh / hybrid_wh
        );
    }
    println!("\n(dropped-out clients burn half their training energy; stragglers are");
    println!(" stopped by the round-end signal; survivors burn the full eq. 35)");
    println!("\nNote the trade-off this exposes (EXPERIMENTS.md §Fig5): the slack");
    println!("factor over-selects to keep rounds quota-fast, which costs device");
    println!("energy — HybridFL wins wall-clock time; the energy claim from the");
    println!("paper only reproduces where over-selection is mild (small C).");
    Ok(())
}
