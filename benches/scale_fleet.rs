//! Bench: virtual-clock rounds at fleet scale — the million-client
//! headline. HybridFL on the mock engine over 100k / 500k / 1M clients
//! (quick mode: 100k only), one/two rounds per cell, reporting round
//! throughput (fleet clients per wall-second), the model-arena peak
//! (must stay O(regions)) and the process peak RSS after each cell.
//! Emits `BENCH_scale.json`.
//!
//! Cells run in ascending fleet order on purpose: `VmHWM` is a
//! process-lifetime high-water mark, so each cell's reading is "the
//! largest fleet so far" — the 1M entry is the one the nightly ceiling
//! watches.
//!
//! Run: `cargo bench --bench scale_fleet` (`--quick` for the CI smoke
//! cell, `--full` for more rounds per cell).

use std::time::Instant;

use hybridfl::benchkit::{peak_rss_bytes, write_report, BenchArgs};
use hybridfl::config::{Dist, EngineKind, ExperimentConfig, ProtocolKind};
use hybridfl::jsonx::Json;
use hybridfl::model;
use hybridfl::scenario::Scenario;

fn cfg_for(n_clients: usize, t_max: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.protocol = ProtocolKind::HybridFl;
    cfg.n_clients = n_clients;
    cfg.n_edges = 16;
    cfg.dataset_size = n_clients * 2; // tiny partitions, huge fleet
    cfg.eval_size = 50;
    cfg.c_fraction = 0.3;
    cfg.dropout = Dist::new(0.2, 0.05);
    cfg.t_max = t_max;
    cfg.seed = 4242;
    cfg
}

fn main() {
    let args = BenchArgs::from_env();
    let cells: &[usize] = if args.quick {
        &[100_000]
    } else {
        &[100_000, 500_000, 1_000_000]
    };
    let rounds_for = |n: usize| -> usize {
        if args.full {
            3
        } else if n >= 1_000_000 {
            1
        } else {
            2
        }
    };

    println!("=== fleet scale: HybridFL virtual-clock rounds, 16 regions ===");
    let mut cell_reports = Vec::new();
    for &n in cells {
        let t_max = rounds_for(n);
        let cfg = cfg_for(n, t_max);

        model::reset_arena_peak();
        let arena_baseline = model::arena_count();
        let t0 = Instant::now();
        let result = Scenario::from_config(cfg).run().expect("scale cell failed");
        let elapsed = t0.elapsed().as_secs_f64();
        let arena_peak = model::arena_peak() - arena_baseline;

        let selected: usize = result
            .rounds
            .iter()
            .map(|r| r.selected.iter().sum::<usize>())
            .sum();
        let submitted: usize = result
            .rounds
            .iter()
            .map(|r| r.submissions.iter().sum::<usize>())
            .sum();
        let clients_per_sec = (n * t_max) as f64 / elapsed;
        let rss = peak_rss_bytes();
        println!(
            "{n:>9} clients  {t_max} round(s) in {elapsed:>7.2}s  \
             {clients_per_sec:>12.0} clients/s  selected {selected}  \
             submitted {submitted}  arena_peak {arena_peak}  peak_rss {}",
            rss.map_or("n/a".into(), |b| format!("{} MiB", b / (1024 * 1024)))
        );

        cell_reports.push(
            Json::obj()
                .set("n_clients", n)
                .set("rounds", t_max)
                .set("run_s", elapsed)
                .set("clients_per_sec", clients_per_sec)
                .set("selected", selected)
                .set("submitted", submitted)
                .set("arena_peak", arena_peak)
                .set(
                    "peak_rss_bytes",
                    rss.map_or(Json::Null, |b| Json::Num(b as f64)),
                ),
        );
    }

    let mode = if args.quick {
        "quick"
    } else if args.full {
        "full"
    } else {
        "default"
    };
    let report = Json::obj()
        .set("bench", "scale_fleet")
        .set("mode", mode)
        .set("cells", Json::Arr(cell_reports));
    write_report("scale", &report);
}
