//! Perf microbenches over the hot paths of all three layers — the §Perf
//! evidence in EXPERIMENTS.md comes from this binary.
//!
//! * L3 coordinator: aggregation axpy bandwidth, slack estimator updates,
//!   client selection, full mock rounds (protocol overhead in isolation).
//! * L1/L2 via PJRT: train-step latency per bucket, eval latency — the
//!   compute the coordinator schedules around.

use std::time::Duration;

use hybridfl::benchkit::{bench, bench_for, black_box, write_report, BenchArgs};
use hybridfl::config::{EngineKind, ExperimentConfig, ProtocolKind};
use hybridfl::jsonx::Json;
use hybridfl::model::{weighted_average, ModelParams};
use hybridfl::rng::Rng;
use hybridfl::selection::SlackEstimator;
use hybridfl::sim::FlRun;

fn lenet_sized_params(seed: u64) -> ModelParams {
    // 44,426 params in LeNet's tensor layout.
    let shapes: Vec<Vec<usize>> = vec![
        vec![25, 6], vec![6], vec![150, 16], vec![16], vec![256, 120],
        vec![120], vec![120, 84], vec![84], vec![84, 10], vec![10],
    ];
    let mut rng = Rng::new(seed);
    let tensors = shapes
        .iter()
        .map(|s| {
            (0..s.iter().product::<usize>())
                .map(|_| rng.normal(0.0, 0.1) as f32)
                .collect()
        })
        .collect();
    ModelParams::new(tensors, shapes)
}

fn main() {
    let args = BenchArgs::from_env();
    let iters = if args.quick { 20 } else { 200 };
    let mut report = Json::obj().set("bench", "perf_hotpath").set("quick", args.quick);

    println!("=== L3 coordinator hot paths ===");

    // Aggregation: EDC-weighted average of 50 LeNet-sized models.
    let models: Vec<ModelParams> = (0..50).map(|i| lenet_sized_params(i)).collect();
    let weighted: Vec<(&ModelParams, f64)> =
        models.iter().map(|m| (m, 100.0)).collect();
    let stats = bench(3, iters.min(100), || {
        black_box(weighted_average(&weighted).unwrap());
    });
    stats.report("aggregate 50 x 44k-param models (axpy)");
    let bytes = 50.0 * 44_426.0 * 4.0;
    println!(
        "  -> {:.2} GB/s effective read bandwidth",
        bytes / stats.mean.as_secs_f64() / 1e9
    );
    report = report
        .set("aggregate_mean_s", stats.mean.as_secs_f64())
        .set("aggregate_gbs", bytes / stats.mean.as_secs_f64() / 1e9);

    // Slack estimator: O(1) per round by design.
    let stats = bench(10, iters, || {
        let mut est = SlackEstimator::new(50, 0.3, 0.5);
        for t in 0..1000 {
            est.observe(black_box(t % 20), t % 3 != 0);
        }
        black_box(est.theta());
    });
    stats.report("slack estimator: 1000 observe() updates");
    report = report.set("slack_1000_updates_mean_s", stats.mean.as_secs_f64());

    // Selection: partial Fisher-Yates over a 500-client region.
    let mut rng = Rng::new(7);
    let stats = bench(10, iters, || {
        black_box(rng.sample_indices(500, 150));
    });
    stats.report("select 150 of 500 clients");
    report = report.set("select_150_of_500_mean_s", stats.mean.as_secs_f64());

    // Trace histogram: the per-observation cost every span/submission
    // pays on the ops scrape path, plus a scrape-sized merge + quantile.
    let mut rng = Rng::new(23);
    let draws: Vec<f64> = (0..1000).map(|_| rng.uniform() * 200.0).collect();
    let stats = bench(10, iters, || {
        let mut h = hybridfl::trace::Histo::new();
        for &v in &draws {
            h.record(black_box(v));
        }
        let mut merged = hybridfl::trace::Histo::new();
        merged.merge(&h);
        black_box(merged.quantile(0.99));
    });
    stats.report("histo: 1000 record + merge + p99");
    report = report.set("histo_1000_record_mean_s", stats.mean.as_secs_f64());

    // Full protocol round, mock engine: pure coordinator overhead.
    let mut cfg = ExperimentConfig::task2_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.n_clients = 500;
    cfg.n_edges = 10;
    cfg.dataset_size = 20_000;
    cfg.eval_size = 100;
    cfg.t_max = 50;
    cfg.protocol = ProtocolKind::HybridFl;
    let stats = bench(1, if args.quick { 3 } else { 10 }, || {
        black_box(FlRun::new(cfg.clone()).unwrap().run().unwrap());
    });
    stats.report("50 rounds x 500 clients, mock engine (full L3 stack)");
    println!(
        "  -> {:.1} us/client-round of coordinator overhead",
        stats.mean.as_secs_f64() * 1e6 / (50.0 * 150.0)
    );
    report = report
        .set("full_stack_50r_mean_s", stats.mean.as_secs_f64())
        .set(
            "coordinator_us_per_client_round",
            stats.mean.as_secs_f64() * 1e6 / (50.0 * 150.0),
        );

    // PJRT train/eval latency (L1+L2 compute the coordinator schedules).
    if hybridfl::runtime::pjrt_available() {
        println!("\n=== L1/L2 via PJRT (real compute) ===");
        use hybridfl::runtime::{build_engine, Engine};
        use std::sync::Arc;

        for (preset, label, part) in [
            (ExperimentConfig::task1_scaled(), "aerofoil train (p64 bucket, tau=5)", 40usize),
            (ExperimentConfig::task2_scaled(), "lenet train (p64 bucket, tau=5)", 50),
        ] {
            let mut cfg = preset;
            cfg.dataset_size = 500;
            cfg.eval_size = 256;
            cfg.n_clients = 5;
            cfg.n_edges = 2;
            let mut rng = Rng::new(1);
            let data = Arc::new(hybridfl::data::build(&cfg, &mut rng));
            let mut engine = build_engine(&cfg, data).unwrap();
            let w0 = engine.init_params();
            let idx: Vec<usize> = (0..part).collect();
            let stats = bench_for(Duration::from_secs(if args.quick { 2 } else { 6 }), || {
                black_box(engine.train_local(&w0, &idx, 5, 0.05).unwrap());
            });
            stats.report(label);
            let stats = bench_for(Duration::from_secs(if args.quick { 2 } else { 4 }), || {
                black_box(engine.evaluate(&w0).unwrap());
            });
            stats.report("  matching eval (256 samples)");
        }
        report = report.set("pjrt", true);
    } else {
        eprintln!("(skipping PJRT section: run `make artifacts`)");
        report = report.set("pjrt", false);
    }

    write_report("perf_hotpath", &report);
}
