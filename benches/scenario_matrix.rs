//! Bench: the selection-zoo evaluation matrix — every adversarial churn
//! scenario × protocol × selector cell (see `harness::matrix`). Prints
//! the grid and emits `BENCH_matrix.json`, which the CI regression gate
//! diffs against the committed `BENCH_matrix.baseline.json` (a >10%
//! round-length regression in any cell fails the build). Every cell of
//! the grid appears in the JSON — a cell that cannot run carries an
//! explicit `skipped` reason rather than vanishing.
//!
//! Run: `cargo bench --bench scenario_matrix` (`--quick` for CI smoke,
//! `--full` for the long horizon).

use hybridfl::benchkit::{bench, black_box, write_report, BenchArgs};
use hybridfl::harness::matrix::{check_complete, report_json, run_matrix, scenarios};
use hybridfl::selection::SelectorKind;

fn main() {
    let args = BenchArgs::from_env();
    let rounds = if args.quick {
        40
    } else if args.full {
        240
    } else {
        120
    };
    let seed = 42;

    let names: Vec<&str> = scenarios(rounds).iter().map(|s| s.name).collect();
    println!(
        "=== scenario matrix: {} scenarios x 3 protocols x {} selectors, {rounds} rounds ===",
        names.len(),
        SelectorKind::ALL.len()
    );
    let cells = run_matrix(rounds, seed).expect("matrix run failed");
    check_complete(rounds, &cells).expect("matrix grid incomplete");

    let mut current = "";
    for c in &cells {
        if c.scenario != current {
            current = c.scenario;
            println!("--- {current} ---");
        }
        println!(
            "{:<10} {:<8} avg_round {:>8.2}s  best_acc {:.4}  sel {:.3}  \
             energy {:.4}Wh  deadline {}/{}",
            c.protocol.as_str(),
            c.selector.as_str(),
            c.avg_round_len,
            c.best_accuracy,
            c.selected_proportion,
            c.mean_device_energy_wh,
            c.deadline_rounds,
            c.rounds
        );
    }

    // Engine throughput of the whole grid at a shortened horizon.
    let iters = if args.quick { 2 } else { 5 };
    let stats = bench(1, iters, || {
        black_box(run_matrix(rounds / 4, seed).expect("timed matrix run failed"));
    });
    stats.report(&format!("matrix: full grid at {} rounds", rounds / 4));

    let report = report_json(rounds, seed, &cells)
        .set("grid_mean_s", stats.mean.as_secs_f64())
        .set("grid_p50_s", stats.p50.as_secs_f64());
    write_report("matrix", &report);
}
