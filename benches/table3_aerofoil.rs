//! Bench: regenerate paper Table III (Task 1: Aerofoil) — the full
//! protocol × E[dr] × C grid with real PJRT training — and print the
//! paper-style rows plus wall-clock cost and shape checks.
//!
//! Run: `cargo bench --bench table3_aerofoil` (≈3 min at scaled preset on
//! one core; `--quick` for the 6-cell smoke grid, `--full` for the exact
//! paper scale).

use std::time::Instant;

use hybridfl::benchkit::{write_report, BenchArgs};
use hybridfl::config::{ProtocolKind, TaskKind};
use hybridfl::harness::sweep::{render_energy, render_table};
use hybridfl::harness::{run_task_sweep, SweepOpts, SweepResult};

fn main() {
    let args = BenchArgs::from_env();
    if !hybridfl::runtime::pjrt_available() {
        eprintln!("table3 bench requires `make artifacts`; skipping");
        let report = hybridfl::jsonx::Json::obj()
            .set("bench", "table3_aerofoil")
            .set("skipped", true)
            .set("reason", "pjrt artifacts unavailable");
        write_report("table3_aerofoil", &report);
        return;
    }
    let opts = SweepOpts {
        full: args.full,
        quick: args.quick,
        ..Default::default()
    };
    let out = std::path::PathBuf::from("reports");
    let t0 = Instant::now();
    let sweep = run_task_sweep(TaskKind::Aerofoil, &opts, &out).unwrap();
    let wall = t0.elapsed();

    print!("{}", render_table(&sweep));
    println!();
    print!("{}", render_energy(&sweep));
    println!(
        "\n{} cells regenerated in {wall:.1?} ({:.2?}/run)",
        sweep.cells.len(),
        wall / sweep.cells.len() as u32
    );
    println!("paper shape checks:");
    shape_checks(&sweep);
    let report = hybridfl::jsonx::Json::obj()
        .set("bench", "table3_aerofoil")
        .set("skipped", false)
        .set("cells", sweep.cells.len())
        .set("wall_s", wall.as_secs_f64());
    write_report("table3_aerofoil", &report);
}

/// The qualitative claims Table III makes, scored on the regenerated data.
fn shape_checks(sweep: &SweepResult) {
    let cell = |p: ProtocolKind, dr: f64, c: f64| {
        sweep
            .cells
            .iter()
            .find(|x| x.protocol == p && (x.e_dr - dr).abs() < 1e-9 && (x.c - c).abs() < 1e-9)
    };
    let (mut len_pass, mut time_pass, mut total) = (0, 0, 0);
    for &dr in &[0.1, 0.3, 0.6] {
        for &c in &[0.1, 0.3, 0.5] {
            let (Some(h), Some(f)) =
                (cell(ProtocolKind::HybridFl, dr, c), cell(ProtocolKind::FedAvg, dr, c))
            else {
                continue;
            };
            total += 1;
            if h.avg_round_len < f.avg_round_len {
                len_pass += 1;
            }
            let ht = h.time_to_target.unwrap_or(f64::MAX);
            let ft = f.time_to_target.unwrap_or(f64::MAX);
            if ht <= ft {
                time_pass += 1;
            }
        }
    }
    println!("  round length: HybridFL < FedAvg in {len_pass}/{total} cells");
    println!("  time-to-target: HybridFL <= FedAvg in {time_pass}/{total} cells");
}
