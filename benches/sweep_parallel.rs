//! Bench: serial vs parallel execution of the Table-III quick grid
//! (mock engine), verifying byte-identical artifacts and recording the
//! wall-clock speedup of the scoped-thread sweep in `BENCH_sweep.json`.
//!
//! Run: `cargo bench --bench sweep_parallel` (`--full` for the full
//! 27-cell paper grid on the mock engine).

use std::time::Instant;

use hybridfl::benchkit::{write_report, BenchArgs};
use hybridfl::config::TaskKind;
use hybridfl::harness::sweep::{render_energy, render_table};
use hybridfl::harness::{run_task_sweep, SweepOpts};
use hybridfl::jsonx::Json;

fn main() {
    let args = BenchArgs::from_env();
    let root = std::env::temp_dir().join("hybridfl_sweep_parallel_bench");
    let _ = std::fs::remove_dir_all(&root);

    let base = SweepOpts {
        quick: !args.full,
        mock: true,
        target: Some(0.3),
        // Inflate the per-cell cost a little so thread-pool overhead is
        // amortized and the speedup is measurable on the mock engine.
        t_max: Some(if args.quick { 400 } else { 1500 }),
        ..Default::default()
    };
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let t0 = Instant::now();
    let serial = run_task_sweep(
        TaskKind::Aerofoil,
        &SweepOpts { parallel: false, ..base.clone() },
        &root.join("serial"),
    )
    .unwrap();
    let t_serial = t0.elapsed();

    let t1 = Instant::now();
    let parallel = run_task_sweep(
        TaskKind::Aerofoil,
        &SweepOpts { parallel: true, ..base },
        &root.join("parallel"),
    )
    .unwrap();
    let t_parallel = t1.elapsed();

    // Correctness gate: the parallel schedule must be invisible in the
    // results.
    assert_eq!(
        render_table(&serial),
        render_table(&parallel),
        "parallel sweep must render identical tables"
    );
    assert_eq!(render_energy(&serial), render_energy(&parallel));

    let cells = serial.cells.len();
    let speedup = t_serial.as_secs_f64() / t_parallel.as_secs_f64().max(1e-9);
    println!("sweep cells          : {cells}");
    println!("worker threads       : {workers}");
    println!("serial wall          : {t_serial:.2?}");
    println!("parallel wall        : {t_parallel:.2?}");
    println!("speedup              : {speedup:.2}x");

    let report = Json::obj()
        .set("bench", "sweep_parallel")
        .set("task", "aerofoil")
        .set("cells", cells)
        .set("worker_threads", workers)
        .set("serial_seconds", t_serial.as_secs_f64())
        .set("parallel_seconds", t_parallel.as_secs_f64())
        .set("speedup", speedup)
        .set("byte_identical", true);
    write_report("sweep", &report);

    let _ = std::fs::remove_dir_all(&root);
}
