//! Bench: regenerate paper Table IV (Task 2: MNIST / LeNet-5, non-IID)
//! with real PJRT training.
//!
//! LeNet execution is the expensive path (~0.1 s per client-round on one
//! CPU core), so this bench defaults to the **quick grid** with a reduced
//! round budget; pass `--grid` semantics via the harness flags:
//!
//! * default        — quick grid (E[dr]=0.3 × C∈{0.1,0.3}), 30 rounds
//! * `--quick`      — same grid, mock engine (plumbing smoke, seconds)
//! * `--full`       — the paper's full 3×3 grid at paper scale (hours;
//!                    documented as out of budget for this box)

use std::time::Instant;

use hybridfl::benchkit::{write_report, BenchArgs};
use hybridfl::config::TaskKind;
use hybridfl::harness::sweep::{render_energy, render_table};
use hybridfl::harness::{run_task_sweep, SweepOpts};

fn main() {
    let args = BenchArgs::from_env();
    if !hybridfl::runtime::pjrt_available() {
        eprintln!("table4 bench requires `make artifacts`; skipping");
        let report = hybridfl::jsonx::Json::obj()
            .set("bench", "table4_mnist")
            .set("skipped", true)
            .set("reason", "pjrt artifacts unavailable");
        write_report("table4_mnist", &report);
        return;
    }
    let opts = SweepOpts {
        full: args.full,
        // Real PJRT on the quick grid unless --quick asks for mock.
        quick: !args.full,
        mock: args.quick,
        t_max: if args.full { None } else { Some(30) },
        ..Default::default()
    };
    let out = std::path::PathBuf::from("reports");
    let t0 = Instant::now();
    let sweep = run_task_sweep(TaskKind::Mnist, &opts, &out).unwrap();
    let wall = t0.elapsed();

    print!("{}", render_table(&sweep));
    println!();
    print!("{}", render_energy(&sweep));
    println!(
        "\n{} cells regenerated in {wall:.1?} ({:.2?}/run)",
        sweep.cells.len(),
        wall / sweep.cells.len() as u32
    );

    // Headline shape: round lengths — the baselines are deadline-bound
    // (~constant ≈ T_lim) while HybridFL's quota trigger cuts them.
    let hybrid_best = sweep
        .cells
        .iter()
        .filter(|c| c.protocol == hybridfl::config::ProtocolKind::HybridFl)
        .map(|c| c.avg_round_len)
        .fold(f64::MAX, f64::min);
    let fedavg_worst = sweep
        .cells
        .iter()
        .filter(|c| c.protocol == hybridfl::config::ProtocolKind::FedAvg)
        .map(|c| c.avg_round_len)
        .fold(0.0, f64::max);
    println!(
        "round-length spread: best HybridFL {hybrid_best:.1}s vs worst FedAvg {fedavg_worst:.1}s \
         ({:.1}x, paper reports up to ~10x at E[dr]=0.6, C=0.1)",
        fedavg_worst / hybrid_best
    );
    let report = hybridfl::jsonx::Json::obj()
        .set("bench", "table4_mnist")
        .set("skipped", false)
        .set("cells", sweep.cells.len())
        .set("wall_s", wall.as_secs_f64())
        .set("round_len_spread", fedavg_worst / hybrid_best);
    write_report("table4_mnist", &report);
}
