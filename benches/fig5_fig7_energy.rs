//! Bench: regenerate the device-energy comparisons of paper Figs. 5
//! (Task 1) and 7 (Task 2): mean on-device Wh to reach the accuracy
//! target per protocol × (E[dr], C).
//!
//! Task 1 runs real PJRT training on the full grid; Task 2 runs the two
//! most telling columns (C = 0.1, 0.3) at a reduced round budget.

use hybridfl::benchkit::{write_report, BenchArgs};
use hybridfl::config::{ExperimentConfig, ProtocolKind, TaskKind};
use hybridfl::metrics::Table;
use hybridfl::sim::FlRun;

fn main() -> hybridfl::Result<()> {
    let args = BenchArgs::from_env();
    if !hybridfl::runtime::pjrt_available() {
        eprintln!("energy bench requires `make artifacts`; skipping");
        let report = hybridfl::jsonx::Json::obj()
            .set("bench", "fig5_fig7_energy")
            .set("skipped", true)
            .set("reason", "pjrt artifacts unavailable");
        write_report("fig5_fig7_energy", &report);
        return Ok(());
    }

    for (task, fig, target, rounds, grid) in [
        (
            TaskKind::Aerofoil,
            "Fig. 5",
            0.65,
            400usize,
            if args.quick {
                vec![(0.3, 0.1)]
            } else {
                vec![(0.1, 0.1), (0.3, 0.1), (0.6, 0.1), (0.3, 0.3), (0.6, 0.3)]
            },
        ),
        (
            TaskKind::Mnist,
            "Fig. 7",
            0.90,
            30,
            if args.quick {
                vec![(0.3, 0.1)]
            } else {
                vec![(0.3, 0.1), (0.6, 0.1), (0.3, 0.3)]
            },
        ),
    ] {
        println!("=== {fig} — mean device energy (Wh) to reach acc={target} ===");
        let mut table = Table::new(&["E[dr]", "C", "fedavg", "hierfavg", "hybridfl"]);
        for &(dr, c) in &grid {
            let mut row = vec![format!("{dr:.1}"), format!("{c:.1}")];
            for proto in ProtocolKind::ALL {
                let mut cfg = match task {
                    TaskKind::Aerofoil => ExperimentConfig::task1_scaled(),
                    TaskKind::Mnist => ExperimentConfig::task2_scaled(),
                };
                let n = cfg.n_clients as f64;
                cfg.protocol = proto;
                cfg.dropout.mean = dr;
                cfg.c_fraction = c;
                cfg.t_max = rounds;
                let result = FlRun::new(cfg)?.run()?;
                let crossing = result
                    .rounds
                    .iter()
                    .find(|r| r.best_accuracy >= target);
                let energy_j = crossing
                    .map(|r| r.cum_energy_j)
                    .unwrap_or_else(|| result.rounds.last().unwrap().cum_energy_j);
                let mark = if crossing.is_some() { "" } else { "*" };
                row.push(format!("{:.3}{mark}", energy_j / 3600.0 / n));
            }
            table.row(row);
        }
        print!("{}", table.render());
        println!("(* = target not reached; energy at t_max)\n");
    }
    let report = hybridfl::jsonx::Json::obj()
        .set("bench", "fig5_fig7_energy")
        .set("skipped", false)
        .set("quick", args.quick);
    write_report("fig5_fig7_energy", &report);
    Ok(())
}
