//! Bench: the checkpoint/replay codecs on a realistic snapshot — a
//! HybridFL run over LeNet-sized models (global + 8 regional arenas of
//! ~44k f32 each) with 100 rounds of trace history. Measures encode /
//! decode latency and effective bandwidth for the binary and JSON codecs
//! and the size ratio between them; emits `BENCH_snapshot.json`.
//!
//! Run: `cargo bench --bench snapshot_codec` (`--quick` for CI smoke).

use hybridfl::benchkit::{bench, black_box, write_report, BenchArgs, Stats};
use hybridfl::config::ExperimentConfig;
use hybridfl::env::{DriverState, RoundTrace};
use hybridfl::jsonx::Json;
use hybridfl::model::ModelParams;
use hybridfl::protocols::ProtocolState;
use hybridfl::rng::Rng;
use hybridfl::selection::SlackEstimator;
use hybridfl::snapshot::{fnv1a64, BinaryCodec, JsonCodec, RunSnapshot, SnapshotCodec};

fn lenet_sized_params(seed: u64) -> ModelParams {
    let shapes: Vec<Vec<usize>> = vec![
        vec![25, 6],
        vec![6],
        vec![150, 16],
        vec![16],
        vec![256, 120],
        vec![120],
        vec![120, 84],
        vec![84],
        vec![84, 10],
        vec![10],
    ];
    let mut rng = Rng::new(seed);
    let tensors = shapes
        .iter()
        .map(|s| {
            (0..s.iter().product::<usize>())
                .map(|_| rng.normal(0.0, 0.1) as f32)
                .collect()
        })
        .collect();
    ModelParams::new(tensors, shapes)
}

fn representative_snapshot(regions: usize, rounds: usize) -> RunSnapshot {
    let mut rng = Rng::new(7);
    let mut slack = Vec::with_capacity(regions);
    for _ in 0..regions {
        let mut est = SlackEstimator::new(60, 0.3, 0.5);
        for t in 0..rounds {
            est.observe(rng.below(20), t % 3 != 0);
        }
        slack.push(est.snapshot());
    }
    let mut driver = DriverState::fresh();
    for t in 1..=rounds {
        driver.cum_time += 40.0 + rng.uniform() * 20.0;
        driver.cum_energy += 500.0 + rng.uniform() * 100.0;
        driver.last_acc = 0.7 * (1.0 - (-(t as f64) / 25.0).exp());
        driver.best_acc = driver.best_acc.max(driver.last_acc);
        driver.last_loss = 1.0 / (1.0 + t as f64);
        driver.rounds.push(RoundTrace {
            t,
            round_len: 40.0,
            cum_time: driver.cum_time,
            accuracy: driver.last_acc,
            best_accuracy: driver.best_acc,
            eval_loss: driver.last_loss,
            selected: vec![20; regions],
            alive: vec![16; regions],
            submissions: vec![12; regions],
            avail: vec![0.7; regions],
            cum_energy_j: driver.cum_energy,
            deadline_hit: t % 5 == 0,
            cloud_aggregated: true,
            slack: None,
        });
        driver.rounds_done = t;
    }
    let config_json = ExperimentConfig::task2_scaled().to_json().dump();
    RunSnapshot {
        backend: "sim".into(),
        fingerprint: fnv1a64(config_json.as_bytes()),
        config_json,
        rng: Rng::new(99).state(),
        // A churning world's state: one Markov flag per client.
        churn: hybridfl::churn::ChurnState::Markov {
            up: (0..500).map(|k| k % 7 != 0).collect(),
        },
        protocol: ProtocolState::HybridFl {
            global: lenet_sized_params(0),
            regionals: (1..=regions as u64).map(lenet_sized_params).collect(),
            slack,
        },
        driver,
    }
}

fn report_codec(
    name: &str,
    codec: &dyn SnapshotCodec,
    snap: &RunSnapshot,
    iters: usize,
) -> (usize, Stats, Stats) {
    let bytes = codec.encode(snap);
    let size = bytes.len();
    let enc = bench(2, iters, || {
        black_box(codec.encode(snap));
    });
    enc.report(&format!("{name}: encode ({size} B)"));
    let dec = bench(2, iters, || {
        black_box(codec.decode(&bytes).unwrap());
    });
    dec.report(&format!("{name}: decode"));
    println!(
        "  -> encode {:.1} MB/s, decode {:.1} MB/s",
        size as f64 / enc.mean.as_secs_f64() / 1e6,
        size as f64 / dec.mean.as_secs_f64() / 1e6
    );
    (size, enc, dec)
}

fn main() {
    let args = BenchArgs::from_env();
    let iters = if args.quick { 10 } else { 100 };
    let (regions, rounds) = if args.full { (16, 400) } else { (8, 100) };

    println!("=== snapshot codecs: {regions}-region HybridFL, {rounds}-round trace ===");
    let snap = representative_snapshot(regions, rounds);

    let (bin_size, bin_enc, bin_dec) = report_codec("binary", &BinaryCodec, &snap, iters);
    let (json_size, json_enc, json_dec) = report_codec("json", &JsonCodec, &snap, iters);
    println!(
        "  -> json/binary size ratio {:.2}x",
        json_size as f64 / bin_size as f64
    );

    // Replay correctness gate: decode(encode(s)) must re-encode to the
    // identical bytes (the determinism the resume tests rely on).
    let bytes = BinaryCodec.encode(&snap);
    let back = BinaryCodec.decode(&bytes).unwrap();
    assert_eq!(bytes, BinaryCodec.encode(&back), "binary codec must be idempotent");

    let report = Json::obj()
        .set("bench", "snapshot_codec")
        .set("regions", regions)
        .set("trace_rounds", rounds)
        .set("binary_bytes", bin_size)
        .set("json_bytes", json_size)
        .set("json_to_binary_ratio", json_size as f64 / bin_size as f64)
        .set("binary_encode_mean_s", bin_enc.mean.as_secs_f64())
        .set("binary_decode_mean_s", bin_dec.mean.as_secs_f64())
        .set("json_encode_mean_s", json_enc.mean.as_secs_f64())
        .set("json_decode_mean_s", json_dec.mean.as_secs_f64())
        .set(
            "binary_encode_mbs",
            bin_size as f64 / bin_enc.mean.as_secs_f64() / 1e6,
        )
        .set(
            "binary_decode_mbs",
            bin_size as f64 / bin_dec.mean.as_secs_f64() / 1e6,
        );
    write_report("snapshot", &report);
}
