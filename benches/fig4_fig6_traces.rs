//! Bench: regenerate the accuracy-trace panels of paper Figs. 4 (Task 1)
//! and 6 (Task 2): per-round global-model accuracy for the three
//! protocols at C ∈ {0.1, 0.3} × E[dr] ∈ {0.3, 0.6} (the paper's most
//! informative panels), written as CSV series and summarized as terminal
//! sparklines.
//!
//! Task 1 runs real PJRT training; Task 2 uses a reduced round budget.

use hybridfl::benchkit::{write_report, BenchArgs};
use hybridfl::config::{ExperimentConfig, ProtocolKind, TaskKind};
use hybridfl::metrics;
use hybridfl::sim::FlRun;

fn spark(series: &[f64]) -> String {
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let hi = series.iter().copied().fold(f64::MIN, f64::max).max(1e-9);
    series
        .iter()
        .map(|&v| GLYPHS[((v / hi) * 7.0).round().clamp(0.0, 7.0) as usize])
        .collect()
}

fn main() -> hybridfl::Result<()> {
    let args = BenchArgs::from_env();
    if !hybridfl::runtime::pjrt_available() {
        eprintln!("traces bench requires `make artifacts`; skipping");
        let report = hybridfl::jsonx::Json::obj()
            .set("bench", "fig4_fig6_traces")
            .set("skipped", true)
            .set("reason", "pjrt artifacts unavailable");
        write_report("fig4_fig6_traces", &report);
        return Ok(());
    }
    let out = std::path::PathBuf::from("reports");
    std::fs::create_dir_all(&out)?;

    for (task, fig, rounds) in [
        (TaskKind::Aerofoil, "fig4", 300usize),
        (TaskKind::Mnist, "fig6", 30),
    ] {
        println!("=== {fig} — accuracy traces ({}) ===", task.as_str());
        let grid: &[(f64, f64)] = if args.quick {
            &[(0.3, 0.1)]
        } else {
            &[(0.3, 0.1), (0.3, 0.3), (0.6, 0.1), (0.6, 0.3)]
        };
        for &(dr, c) in grid {
            println!("panel E[dr]={dr}, C={c}:");
            for proto in ProtocolKind::ALL {
                let mut cfg = match task {
                    TaskKind::Aerofoil => ExperimentConfig::task1_scaled(),
                    TaskKind::Mnist => ExperimentConfig::task2_scaled(),
                };
                cfg.protocol = proto;
                cfg.dropout.mean = dr;
                cfg.c_fraction = c;
                cfg.t_max = rounds;
                let schema = metrics::CsvSchema::from_config(&cfg);
                let result = FlRun::new(cfg)?.run()?;
                // Sample 40 points for the sparkline.
                let step = (result.rounds.len() / 40).max(1);
                let series: Vec<f64> = result
                    .rounds
                    .iter()
                    .step_by(step)
                    .map(|r| r.best_accuracy)
                    .collect();
                println!(
                    "  {:<9} {}  (best {:.3})",
                    proto.as_str(),
                    spark(&series),
                    result.summary.best_accuracy
                );
                metrics::write_csv_with(
                    &out.join(format!(
                        "{fig}_dr{dr}_c{c}_{}.csv",
                        proto.as_str()
                    )),
                    &schema,
                    &result.rounds,
                )?;
            }
        }
    }
    println!("CSV series -> reports/fig4_*.csv, reports/fig6_*.csv");
    let report = hybridfl::jsonx::Json::obj()
        .set("bench", "fig4_fig6_traces")
        .set("skipped", false)
        .set("quick", args.quick);
    write_report("fig4_fig6_traces", &report);
    Ok(())
}
