//! Bench: protocol dynamics under non-stationary churn — the dynamic
//! Fig. 2 story. HybridFL vs FedAvg vs HierFAVG on a two-region fleet
//! under bursty Markov availability plus a scripted drop-out step change
//! (region 1, mid-run): round lengths, convergence, deadline pressure,
//! and how fast HybridFL's selected proportion re-converges after the
//! regime shift. Emits `BENCH_churn.json`.
//!
//! Run: `cargo bench --bench churn_adaptivity` (`--quick` for CI smoke,
//! `--full` for the long horizon).

use hybridfl::benchkit::{bench, black_box, write_report, BenchArgs};
use hybridfl::churn::{ChurnModel, FaultEvent};
use hybridfl::config::{Dist, EngineKind, ExperimentConfig, ProtocolKind, RegionSpec};
use hybridfl::env::RunResult;
use hybridfl::jsonx::Json;
use hybridfl::scenario::Scenario;

fn base_cfg(protocol: ProtocolKind, t_max: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.protocol = protocol;
    cfg.n_clients = 40;
    cfg.n_edges = 2;
    cfg.regions = vec![
        RegionSpec { n_clients: 20, dropout_mean: 0.3 },
        RegionSpec { n_clients: 20, dropout_mean: 0.3 },
    ];
    cfg.dropout = Dist::new(0.3, 0.02);
    cfg.c_fraction = 0.3;
    cfg.dataset_size = 800;
    cfg.eval_size = 50;
    cfg.t_max = t_max;
    cfg.seed = 42;
    cfg
}

fn churn(shift_at: usize) -> ChurnModel {
    ChurnModel::Composed {
        layers: vec![
            ChurnModel::MarkovOnOff {
                p_fail: 0.08,
                p_recover: 0.3,
                down_dropout: 0.97,
                region_scale: Vec::new(),
            },
            ChurnModel::FaultScript {
                events: vec![FaultEvent::DropoutShift {
                    region: Some(1),
                    at_round: shift_at,
                    delta: 0.3,
                }],
            },
        ],
    }
}

fn run(protocol: ProtocolKind, t_max: usize, shift_at: usize) -> RunResult {
    Scenario::from_config(base_cfg(protocol, t_max))
        .churn(churn(shift_at))
        .run()
        .expect("churn run failed")
}

/// Rounds after the shift until the trailing-10 mean alive fraction of
/// the degraded region recovers to within 0.05 of its pre-shift mean
/// (None = never within the run).
fn reconverge_rounds(result: &RunResult, shift_at: usize, n_r: f64) -> Option<usize> {
    let frac: Vec<f64> = result
        .rounds
        .iter()
        .map(|r| r.alive[1] as f64 / n_r)
        .collect();
    let window = 10usize;
    let pre_lo = shift_at.saturating_sub(1 + 2 * window);
    let pre: f64 = frac[pre_lo..shift_at - 1].iter().sum::<f64>()
        / (shift_at - 1 - pre_lo) as f64;
    for end in (shift_at + window)..=frac.len() {
        let mean: f64 = frac[end - window..end].iter().sum::<f64>() / window as f64;
        if mean >= pre - 0.05 {
            // rounds[end - 1] is round t = end.
            return Some(end - shift_at);
        }
    }
    None
}

fn main() {
    let args = BenchArgs::from_env();
    let (t_max, shift_at) = if args.quick {
        (80, 30)
    } else if args.full {
        (400, 120)
    } else {
        (240, 80)
    };

    println!("=== churn adaptivity: Markov + drop-out step @round {shift_at}, {t_max} rounds ===");
    let mut protocols = Json::obj();
    let mut hybrid_reconverge: Option<usize> = None;
    for p in ProtocolKind::ALL {
        let result = run(p, t_max, shift_at);
        let s = &result.summary;
        let deadline_rounds = result.rounds.iter().filter(|r| r.deadline_hit).count();
        let post_avg_len: f64 = {
            let post: Vec<f64> = result
                .rounds
                .iter()
                .filter(|r| r.t >= shift_at)
                .map(|r| r.round_len)
                .collect();
            post.iter().sum::<f64>() / post.len().max(1) as f64
        };
        println!(
            "{:<10} avg_round {:>8.2}s  post-shift avg {:>8.2}s  best_acc {:.4}  deadline {}/{}",
            p.as_str(),
            s.avg_round_len,
            post_avg_len,
            s.best_accuracy,
            deadline_rounds,
            result.rounds.len()
        );
        let mut entry = Json::obj()
            .set("avg_round_len_s", s.avg_round_len)
            .set("post_shift_avg_round_len_s", post_avg_len)
            .set("best_accuracy", s.best_accuracy)
            .set("deadline_rounds", deadline_rounds)
            .set("rounds", result.rounds.len());
        if p == ProtocolKind::HybridFl {
            hybrid_reconverge = reconverge_rounds(&result, shift_at, 20.0);
            entry = entry.set(
                "reconverge_rounds",
                hybrid_reconverge.map_or(Json::Null, |r| Json::Num(r as f64)),
            );
            println!(
                "           selected-proportion re-convergence: {}",
                hybrid_reconverge
                    .map_or("not within run".into(), |r| format!("{r} rounds after shift"))
            );
        }
        protocols = protocols.set(p.as_str(), entry);
    }

    // Engine throughput of one full churning HybridFL run.
    let iters = if args.quick { 3 } else { 10 };
    let stats = bench(1, iters, || {
        black_box(run(ProtocolKind::HybridFl, t_max, shift_at));
    });
    stats.report(&format!("churn: {t_max}-round HybridFL run (markov+shift)"));

    let report = Json::obj()
        .set("bench", "churn_adaptivity")
        .set("t_max", t_max)
        .set("shift_at", shift_at)
        .set("protocols", protocols)
        .set(
            "hybrid_reconverge_rounds",
            hybrid_reconverge.map_or(Json::Null, |r| Json::Num(r as f64)),
        )
        .set("run_mean_s", stats.mean.as_secs_f64())
        .set("run_p50_s", stats.p50.as_secs_f64());
    write_report("churn", &report);
}
