//! Bench: regenerate paper Fig. 2 (slack/selection traces) and time the
//! protocol-only round engine.
//!
//! Run: `cargo bench --bench fig2_slack` (`--full` for 10 repetitions with
//! distinct seeds, reporting trace variance).

use hybridfl::benchkit::{bench, black_box, write_report, BenchArgs};
use hybridfl::harness::{fig2, run_fig2};
use hybridfl::jsonx::Json;

fn main() {
    let args = BenchArgs::from_env();
    let out = std::path::PathBuf::from("reports");

    println!("=== Fig. 2 — regional slack factor traces ===");
    let seeds: Vec<u64> = if args.full { (40..50).collect() } else { vec![42] };
    let mut deadline_rounds = 0usize;
    let mut total_rounds = 0usize;
    for seed in &seeds {
        let (result, stats) = run_fig2(&out, *seed).unwrap();
        println!("seed {seed}:");
        print!("{}", fig2::render_stats(&stats));
        println!(
            "  ({} rounds, {} deadline-bound)",
            result.rounds.len(),
            result.rounds.iter().filter(|r| r.deadline_hit).count()
        );
        total_rounds += result.rounds.len();
        deadline_rounds += result.rounds.iter().filter(|r| r.deadline_hit).count();
    }

    // Engine throughput: the 100-round protocol-only run.
    let stats = bench(1, if args.quick { 3 } else { 10 }, || {
        let dir = std::env::temp_dir().join("hybridfl_fig2_bench");
        black_box(run_fig2(&dir, 42).unwrap());
    });
    stats.report("fig2: 100-round HybridFL run (mock engine)");

    let report = Json::obj()
        .set("bench", "fig2_slack")
        .set("seeds", seeds.len())
        .set("total_rounds", total_rounds)
        .set("deadline_rounds", deadline_rounds)
        .set("run_mean_s", stats.mean.as_secs_f64())
        .set("run_p50_s", stats.p50.as_secs_f64());
    write_report("fig2_slack", &report);
}
