//! Bench: the contiguous-arena `ModelParams` data plane against the
//! pre-refactor nested-`Vec<Vec<f32>>` layout, and streaming edge
//! aggregation against the old buffer-then-aggregate round. Emits
//! `BENCH_params.json` (the params-trajectory seed) via `jsonx`.
//!
//! Two questions, matching the acceptance criteria of the refactor:
//!
//! 1. **Hot path** — does the flat chunked `axpy` at least match the
//!    nested scalar loops on `weighted_average` over LeNet-sized models?
//! 2. **Round shape** — does streaming (fold each submission on arrival,
//!    drop it) beat buffering all submissions before aggregating, and
//!    does it eliminate the O(submissions) resident-model peak? Peaks are
//!    measured with the arena instrumentation in `hybridfl::model`.
//!
//! Run: `cargo bench --bench params_hotpath` (`--quick` for CI smoke).

use hybridfl::aggregation::{edc_cloud, regional_with_cache, StreamingAggregator};
use hybridfl::benchkit::{bench, black_box, write_report, BenchArgs, Stats};
use hybridfl::jsonx::Json;
use hybridfl::model::{self, weighted_average, ModelParams};
use hybridfl::rng::Rng;

/// The pre-refactor parameter layout — one heap `Vec<f32>` per tensor,
/// scalar accumulate loops — kept here as the baseline under test.
struct NestedParams {
    tensors: Vec<Vec<f32>>,
}

impl NestedParams {
    fn zeros_like(&self) -> NestedParams {
        NestedParams {
            tensors: self.tensors.iter().map(|t| vec![0.0; t.len()]).collect(),
        }
    }

    fn axpy(&mut self, a: f32, x: &NestedParams) {
        for (dst, src) in self.tensors.iter_mut().zip(x.tensors.iter()) {
            for (d, &s) in dst.iter_mut().zip(src.iter()) {
                *d += a * s;
            }
        }
    }
}

fn nested_weighted_average(models: &[(&NestedParams, f64)]) -> Option<NestedParams> {
    let total: f64 = models.iter().map(|(_, w)| *w).sum();
    if models.is_empty() || total <= f64::EPSILON {
        return None;
    }
    let mut out = models[0].0.zeros_like();
    for (m, w) in models {
        out.axpy((*w / total) as f32, m);
    }
    Some(out)
}

/// 44,426 params in LeNet's tensor layout.
fn lenet_shapes() -> Vec<Vec<usize>> {
    vec![
        vec![25, 6],
        vec![6],
        vec![150, 16],
        vec![16],
        vec![256, 120],
        vec![120],
        vec![120, 84],
        vec![84],
        vec![84, 10],
        vec![10],
    ]
}

fn random_tensors(seed: u64, shapes: &[Vec<usize>]) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    shapes
        .iter()
        .map(|s| {
            (0..s.iter().product::<usize>())
                .map(|_| rng.normal(0.0, 0.1) as f32)
                .collect()
        })
        .collect()
}

fn main() {
    let args = BenchArgs::from_env();
    let iters = if args.quick { 10 } else { 100 };
    let shapes = lenet_shapes();
    let n_values: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    let n_models = 50usize;

    println!("=== arena vs nested: weighted_average of {n_models} x {n_values}-param models ===");

    let arena_models: Vec<ModelParams> = (0..n_models as u64)
        .map(|i| ModelParams::new(random_tensors(i, &shapes), shapes.clone()))
        .collect();
    let arena_weighted: Vec<(&ModelParams, f64)> =
        arena_models.iter().map(|m| (m, 100.0)).collect();
    let arena_stats = bench(3, iters, || {
        black_box(weighted_average(&arena_weighted).unwrap());
    });
    arena_stats.report("arena axpy (flat chunked)");

    let nested_models: Vec<NestedParams> = (0..n_models as u64)
        .map(|i| NestedParams {
            tensors: random_tensors(i, &shapes),
        })
        .collect();
    let nested_weighted: Vec<(&NestedParams, f64)> =
        nested_models.iter().map(|m| (m, 100.0)).collect();
    let nested_stats = bench(3, iters, || {
        black_box(nested_weighted_average(&nested_weighted).unwrap());
    });
    nested_stats.report("nested axpy (per-tensor scalar)");

    let gbs = |s: &Stats| n_models as f64 * n_values as f64 * 4.0 / s.mean.as_secs_f64() / 1e9;
    println!(
        "  -> arena {:.2} GB/s, nested {:.2} GB/s, speedup {:.2}x",
        gbs(&arena_stats),
        gbs(&nested_stats),
        nested_stats.mean.as_secs_f64() / arena_stats.mean.as_secs_f64().max(1e-12)
    );

    // --- streaming vs buffered round aggregation ---------------------------
    // One quota round: `subs` submissions spread over `m` regions. The
    // buffered arm reproduces the old data plane (materialize every
    // arrival, then regional_with_cache + edc_cloud); the streaming arm
    // folds each submission on arrival and never buffers.
    println!("\n=== streaming vs buffered round aggregation ===");
    let m = 8usize;
    let subs = if args.quick { 64 } else { 256 };
    let round_iters = if args.quick { 5 } else { 30 };
    let template = arena_models[0].zeros_like();
    let prevs: Vec<ModelParams> = (0..m as u64)
        .map(|r| ModelParams::new(random_tensors(1000 + r, &shapes), shapes.clone()))
        .collect();
    let d_k = 100.0f64;
    // Half coverage: every region holds twice the data its submitters carry.
    let region_data: Vec<f64> = (0..m)
        .map(|r| {
            let in_region = (subs + m - 1 - r) / m; // ceil split of subs over m
            (in_region as f64 * d_k * 2.0).max(d_k)
        })
        .collect();
    // Stand-in for one client's training output (COW copy of the start).
    let make_model = |i: usize| -> ModelParams {
        let mut w = arena_models[i % n_models].clone();
        w.values_mut()[i % n_values] += 1e-3 * i as f32;
        w
    };

    let buffered_round = || {
        let mut arrivals: Vec<(usize, ModelParams, f64)> = Vec::with_capacity(subs);
        for i in 0..subs {
            arrivals.push((i % m, make_model(i), d_k));
        }
        let mut regionals: Vec<(ModelParams, f64)> = Vec::with_capacity(m);
        for r in 0..m {
            let models: Vec<(&ModelParams, f64)> = arrivals
                .iter()
                .filter(|(rr, _, _)| *rr == r)
                .map(|(_, w, d)| (w, *d))
                .collect();
            let edc: f64 = models.iter().map(|(_, d)| *d).sum();
            let w = regional_with_cache(&models, region_data[r], &prevs[r]).unwrap();
            regionals.push((w, edc));
        }
        let refs: Vec<(&ModelParams, f64)> = regionals.iter().map(|(w, e)| (w, *e)).collect();
        edc_cloud(&refs).unwrap()
    };
    let streaming_round = || {
        let mut agg = StreamingAggregator::for_regions(&region_data, &template);
        for i in 0..subs {
            let w = make_model(i);
            agg.fold(i % m, &w, d_k, 0.5).unwrap();
        }
        agg.cloud_with_cache(&prevs).unwrap().unwrap()
    };

    // Peak resident-arena measurement: one representative run per arm.
    model::reset_arena_peak();
    let baseline = model::arena_count();
    black_box(buffered_round());
    let peak_buffered = model::arena_peak() - baseline;
    model::reset_arena_peak();
    black_box(streaming_round());
    let peak_streaming = model::arena_peak() - baseline;

    let buffered_stats = bench(2, round_iters, || {
        black_box(buffered_round());
    });
    buffered_stats.report(&format!("buffered round ({subs} subs, {m} regions)"));
    let streaming_stats = bench(2, round_iters, || {
        black_box(streaming_round());
    });
    streaming_stats.report(&format!("streaming round ({subs} subs, {m} regions)"));
    println!(
        "  -> peak resident models: buffered {peak_buffered}, streaming {peak_streaming} \
         (submissions per round: {subs})"
    );
    assert!(
        peak_streaming < peak_buffered,
        "streaming must not buffer per-submission models"
    );

    let report = Json::obj()
        .set("bench", "params_hotpath")
        .set("model_values", n_values)
        .set("models", n_models)
        .set("arena_axpy_mean_s", arena_stats.mean.as_secs_f64())
        .set("nested_axpy_mean_s", nested_stats.mean.as_secs_f64())
        .set(
            "axpy_speedup",
            nested_stats.mean.as_secs_f64() / arena_stats.mean.as_secs_f64().max(1e-12),
        )
        .set("arena_bandwidth_gbs", gbs(&arena_stats))
        .set("nested_bandwidth_gbs", gbs(&nested_stats))
        .set("round_submissions", subs)
        .set("round_regions", m)
        .set("buffered_round_mean_s", buffered_stats.mean.as_secs_f64())
        .set("streaming_round_mean_s", streaming_stats.mean.as_secs_f64())
        .set(
            "round_speedup",
            buffered_stats.mean.as_secs_f64() / streaming_stats.mean.as_secs_f64().max(1e-12),
        )
        .set("peak_models_buffered", peak_buffered)
        .set("peak_models_streaming", peak_streaming);
    write_report("params", &report);
}
