//! Bench: communication-efficient submission paths — the codec × protocol
//! trade-off surface vs the dense baseline (see `hybridfl::comm`). Every
//! cell reports the bytes actually moved device→edge, the mean round
//! length, the best accuracy, and the mean device energy, so the JSON
//! shows directly what a codec buys (shorter uploads, lower energy) and
//! what it costs (accuracy drift). A final pair of cells pits a relay
//! quantile against the plain dense run to show relay-assisted upload
//! shortening the straggler-bound round.
//!
//! Emits `BENCH_comm.json` — a required artifact of the CI `bench · smoke`
//! job. The ≥4× byte reduction of `topk:0.05+ef` vs dense is asserted
//! here (it is structural: 8 bytes × k kept coordinates vs 4 bytes × n),
//! the accuracy drift is reported, not asserted.
//!
//! Run: `cargo bench --bench comm_tradeoff` (`--quick` for CI smoke,
//! `--full` for the long horizon).

use hybridfl::benchkit::{bench, black_box, write_report, BenchArgs};
use hybridfl::comm::CommConfig;
use hybridfl::config::ProtocolKind;
use hybridfl::jsonx::Json;
use hybridfl::scenario::Scenario;
use hybridfl::sim::RunResult;

/// The codec axis: the dense baseline first, then each compressed path.
const CODECS: &[&str] = &["dense", "f16", "i8", "topk:0.05+ef"];

/// The relay quantile of the relay-vs-no-relay pair.
const RELAY_Q: f64 = 0.25;

fn run_cell(spec: &str, protocol: ProtocolKind, rounds: usize, seed: u64) -> (RunResult, u64) {
    let mut cfg = hybridfl::sim::test_support::hetero_two_region_cfg(0.2, 0.4);
    cfg.name = "comm-tradeoff".into();
    cfg.protocol = protocol;
    cfg.t_max = rounds;
    cfg.seed = seed;
    let comm = CommConfig::parse_spec(spec).expect("bench codec spec must parse");
    let result = Scenario::from_config(cfg)
        .comm(comm)
        .run()
        .unwrap_or_else(|e| panic!("cell {spec}/{} failed: {e:#}", protocol.as_str()));
    let bytes: u64 = result.rounds.iter().map(|r| r.bytes_moved).sum();
    (result, bytes)
}

fn main() {
    let args = BenchArgs::from_env();
    let rounds = if args.quick {
        16
    } else if args.full {
        160
    } else {
        48
    };
    let seed = 42;

    println!(
        "=== comm trade-off: {} codecs x {} protocols, {rounds} rounds ===",
        CODECS.len(),
        ProtocolKind::ALL.len()
    );

    let mut cell_rows: Vec<Json> = Vec::new();
    let mut topk_gate: Option<(f64, f64)> = None; // (byte_reduction, acc_delta) on hybridfl
    for protocol in ProtocolKind::ALL {
        let (dense, dense_bytes) = run_cell("dense", protocol, rounds, seed);
        for spec in CODECS {
            let (result, bytes) = if *spec == "dense" {
                (dense.clone(), dense_bytes)
            } else {
                run_cell(spec, protocol, rounds, seed)
            };
            // 0.0 marks an empty cell (nothing folded); keeps the JSON finite.
            let reduction = if bytes > 0 {
                dense_bytes as f64 / bytes as f64
            } else {
                0.0
            };
            let acc_delta = dense.summary.best_accuracy - result.summary.best_accuracy;
            println!(
                "{:<8} {:<12} bytes {:>12}  x{:<6.1} vs dense  avg_round {:>8.2}s  \
                 best_acc {:.4} (Δ {:+.4})  energy {:.4}Wh",
                protocol.as_str(),
                spec,
                bytes,
                reduction,
                result.summary.avg_round_len,
                result.summary.best_accuracy,
                -acc_delta,
                result.summary.mean_device_energy_wh,
            );
            if *spec == "topk:0.05+ef" {
                assert!(
                    bytes > 0 && reduction >= 4.0,
                    "topk:0.05+ef moved {bytes} bytes vs dense {dense_bytes} on {} — \
                     expected a >=4x reduction",
                    protocol.as_str()
                );
                if protocol == ProtocolKind::HybridFl {
                    topk_gate = Some((reduction, acc_delta));
                }
            }
            cell_rows.push(
                Json::obj()
                    .set("codec", *spec)
                    .set("protocol", protocol.as_str())
                    .set("rounds", result.rounds.len())
                    .set("bytes_total", bytes)
                    .set("byte_reduction_vs_dense", reduction)
                    .set("avg_round_len_s", result.summary.avg_round_len)
                    .set("best_accuracy", result.summary.best_accuracy)
                    .set("accuracy_delta_vs_dense", acc_delta)
                    .set(
                        "mean_device_energy_wh",
                        result.summary.mean_device_energy_wh,
                    ),
            );
        }
    }
    let (topk_reduction, topk_acc_delta) =
        topk_gate.expect("the hybridfl topk cell always runs");

    // Relay pair: same world, same dense codec, with and without the
    // relay quantile. Relay pays off when the round is *straggler-bound*
    // and the fleet's bandwidths are genuinely heterogeneous (the relay
    // detour costs 2·upload/bps_strong, so it must undercut
    // 1·upload/bps_weak) — so this pair runs FedAvg (AllSelected cut:
    // the round waits for the slowest survivor) over a wide bandwidth
    // spread. Under HybridFL's quota cut the weak tail is already
    // outside the round and relaying can even delay the quota.
    let relay_pair = |spec: &str| -> RunResult {
        let mut cfg = hybridfl::sim::test_support::hetero_two_region_cfg(0.2, 0.4);
        cfg.name = "comm-relay".into();
        cfg.protocol = ProtocolKind::FedAvg;
        cfg.bw_mhz = hybridfl::config::Dist::new(0.5, 0.3);
        cfg.t_max = rounds;
        cfg.seed = seed;
        let comm = CommConfig::parse_spec(spec).expect("relay spec must parse");
        Scenario::from_config(cfg)
            .comm(comm)
            .run()
            .unwrap_or_else(|e| panic!("relay cell {spec} failed: {e:#}"))
    };
    let no_relay = relay_pair("dense");
    let with_relay = relay_pair(&format!("relay:{RELAY_Q}"));
    let relay_speedup = no_relay.summary.avg_round_len / with_relay.summary.avg_round_len;
    println!(
        "relay:{RELAY_Q} on fedavg: avg_round {:.2}s vs {:.2}s dense (speedup x{:.3})",
        with_relay.summary.avg_round_len, no_relay.summary.avg_round_len, relay_speedup
    );

    // Engine throughput of one compressed run at a shortened horizon.
    let iters = if args.quick { 2 } else { 5 };
    let stats = bench(1, iters, || {
        black_box(run_cell(
            "topk:0.05+ef",
            ProtocolKind::HybridFl,
            (rounds / 4).max(2),
            seed,
        ));
    });
    stats.report(&format!(
        "comm: topk+ef hybridfl run at {} rounds",
        (rounds / 4).max(2)
    ));

    let codec_names: Vec<&str> = CODECS.to_vec();
    let protocol_names: Vec<&str> = ProtocolKind::ALL.iter().map(|p| p.as_str()).collect();
    let report = Json::obj()
        .set("bench", "comm_tradeoff")
        .set("rounds", rounds)
        .set("seed", seed)
        .set(
            "grid",
            Json::obj()
                .set("codecs", codec_names)
                .set("protocols", protocol_names),
        )
        .set("cells", Json::Arr(cell_rows))
        .set(
            "topk_vs_dense",
            Json::obj()
                .set("byte_reduction", topk_reduction)
                .set("accuracy_delta", topk_acc_delta)
                .set("within_1pct", topk_acc_delta.abs() <= 0.01),
        )
        .set(
            "relay",
            Json::obj()
                .set("protocol", "fedavg")
                .set("quantile", RELAY_Q)
                .set("avg_round_len_s", with_relay.summary.avg_round_len)
                .set("dense_avg_round_len_s", no_relay.summary.avg_round_len)
                .set("speedup", relay_speedup),
        )
        .set("run_mean_s", stats.mean.as_secs_f64())
        .set("run_p50_s", stats.p50.as_secs_f64());
    write_report("comm", &report);
}
